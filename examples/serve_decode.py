"""Batched serving: prefill a prompt batch + greedy decode with KV caches.

Uses the reduced qwen3 config on CPU; on TPU the same driver serves the full
assigned configs (see repro/launch/serve.py for the production entry).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.configs import get_arch, plan_for_mesh, smoke_of
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import serve

arch = smoke_of(get_arch("qwen3-0.6b"))
mesh = make_local_mesh()
plan = plan_for_mesh(mesh)

tokens, stats = serve(arch, mesh, plan, batch=4, prompt_len=64, gen=24)
print("generated:", tokens.shape, "first row:", tokens[0][:10].tolist())
print(f"prefill {stats['prefill_s']*1e3:.0f} ms, "
      f"decode {stats['decode_s']*1e3:.0f} ms "
      f"({stats['tok_per_s']:.1f} tok/s on 1 CPU core)")

# MLA architecture: decode runs against the compressed latent cache
arch2 = smoke_of(get_arch("minicpm3-4b"))
tokens2, stats2 = serve(arch2, mesh, plan, batch=2, prompt_len=32, gen=8)
print(f"minicpm3 (MLA absorbed decode): {tokens2.shape}, "
      f"{stats2['tok_per_s']:.1f} tok/s")
