"""The paper's "speed" vs "quality" presets (§4.3) + real shard_map execution.

- "speed"  : First Fit + Internal-First ordering, no recoloring
- "quality": Random-10 Fit + Internal-First + 1 ND recoloring iteration

Also runs the SAME SPMD code over a real multi-device mesh when more than one
XLA device is available (set XLA_FLAGS=--xla_force_host_platform_device_count=8).

Run:  PYTHONPATH=src python examples/distributed_coloring.py
"""
import time

import jax
import numpy as np

from repro.core import (check_coloring, colors_from_views, partition_graph,
                        presets, rmat)

g = rmat.rmat_er(14, 8, seed=1)
P = 8
pg = partition_graph(g, P)
print(f"graph: |V|={g.n:,} |E|={g.m:,} maxdeg={g.max_degree}, P={P}\n")

for preset in (presets.speed(), presets.quality(x=10)):
    t0 = time.time()
    view, log = presets.run_preset(pg, preset)
    dt = time.time() - t0
    colors = colors_from_views(pg, np.asarray(view))
    st = check_coloring(g, colors)
    print(f"preset={preset.name!r:10s} -> {st['n_colors']:3d} colors, "
          f"valid={st['valid']}, {dt:.2f}s")
    for entry in log:
        stage = entry.pop("stage")
        print(f"   {stage}: { {k: v for k, v in entry.items() if isinstance(v, (int, str))} }")

# real sharded execution if the process has multiple devices
if len(jax.devices()) >= P:
    from repro.compat import make_mesh
    from repro.core import ColorConfig, color_graph_sharded, compute_order, ordering
    mesh = make_mesh((P,), ("workers",))
    order = compute_order(pg, ordering.INTERNAL_FIRST)
    view, stats = color_graph_sharded(pg, order,
                                      ColorConfig(max_colors=1024,
                                                  superstep=512), mesh)
    print(f"\nshard_map over {P} real devices: {stats['n_colors']} colors")
else:
    print(f"\n({len(jax.devices())} device(s) — rerun with "
          f"XLA_FLAGS=--xla_force_host_platform_device_count={P} for the "
          f"real shard_map path)")
