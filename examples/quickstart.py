"""Quickstart: distributed graph coloring with iterative recoloring.

Colors an RMAT graph on 8 (simulated) processors, then improves the coloring
with ND recoloring iterations — the paper's core loop in ~30 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (ColorConfig, RecolorConfig, check_coloring,
                        color_graph_sim, colors_from_views, compute_order,
                        ordering, partition_graph, recolor_iterations, rmat)

# 1. a graph (16k vertices, power-law degrees) partitioned over 8 workers
g = rmat.rmat_good(14, 8, seed=1)
pg = partition_graph(g, P=8)
print(f"graph: |V|={g.n:,} |E|={g.m:,} maxdeg={g.max_degree}")

# 2. speculative greedy coloring (Bozdağ framework): supersteps + conflict
#    resolution rounds, First Fit selection, Smallest Last local ordering
order = compute_order(pg, ordering.SMALLEST_LAST)
cfg = ColorConfig(max_colors=1024, superstep=512)
view, stats = color_graph_sim(pg, order, cfg)
colors = colors_from_views(pg, np.asarray(view))
print(f"initial: {stats['n_colors']} colors in {stats['n_rounds']} rounds "
      f"({stats['n_exchanges']} boundary exchanges), "
      f"valid={check_coloring(g, colors)['valid']}")

# 3. iterative recoloring (the paper's contribution): each iteration colors
#    whole color classes in parallel — conflict-free by construction — with
#    piggybacked (coalesced) boundary exchanges
view, hist = recolor_iterations(pg, np.asarray(view), n_iters=5,
                                cfg=RecolorConfig(max_colors=1024),
                                base_perm="nd")
for h in hist:
    print(f"  RC iter {h['iteration']} ({h['perm']}): {h['n_colors']} colors, "
          f"{h['n_exchanges']}/{h['n_steps']} exchanges executed")
colors = colors_from_views(pg, np.asarray(view))
final = check_coloring(g, colors)
print(f"final: {final['n_colors']} colors, valid={final['valid']}")
