"""Quickstart: distributed graph coloring with iterative recoloring.

Colors an RMAT graph on 8 (simulated) processors with the paper's
"quality" preset — Random-X Fit seeding + ND recoloring — through the
fused device-resident pipeline: initial coloring plus every recoloring
iteration in ONE jitted program (DESIGN.md §7).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (check_coloring, colors_from_views, compute_order,
                        partition_graph, pipeline_sim, presets, rmat)

# 1. a graph (16k vertices, power-law degrees) partitioned over 8 workers
g = rmat.rmat_good(14, 8, seed=1)
pg = partition_graph(g, P=8)
print(f"graph: |V|={g.n:,} |E|={g.m:,} maxdeg={g.max_degree}")

# 2. the paper's "quality" parameter set (§4.3): Random-X Fit selection,
#    Internal-First ordering, ND recoloring — as one fused pipeline config.
#    presets.speed() is the no-recoloring counterpart.
preset = presets.quality(x=10)
cfg = presets.pipeline_config(preset, n_iters=5, patience=2)
order = compute_order(pg, preset.ordering)

# 3. one device-resident program: speculative coloring + up to 5 recoloring
#    iterations (adaptive stop after 2 non-improving ones), per-iteration
#    stats unpacked once at the end
view, res = pipeline_sim(pg, order, cfg)
print(f"initial: {res['color']['n_colors_distinct']} colors in "
      f"{res['color']['n_rounds']} rounds "
      f"({res['color']['n_exchanges']} boundary exchanges)")
for h in res["history"]:
    print(f"  RC iter {h['iteration']} ({h['perm']}): "
          f"{h['n_colors_distinct']} colors, "
          f"{h['n_exchanges']}/{h['n_steps']} exchanges executed")

colors = colors_from_views(pg, np.asarray(view))
final = check_coloring(g, colors)
print(f"final: {final['n_colors']} colors after {res['n_iters_run']} "
      f"iterations, valid={final['valid']}")
