"""End-to-end training driver: ~100M-parameter LM, a few hundred steps.

Builds a ~100M-param qwen3-family model, trains it on the synthetic bigram
stream with checkpointing and an injected mid-run failure (recovered
automatically), and prints the loss curve.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(On this CPU container a 100M model step is slow; --tiny uses the smoke size.)
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_arch, plan_for_mesh, smoke_of
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.train import FailureInjector, OptConfig, Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
args = ap.parse_args()

base = get_arch("qwen3-0.6b")
if args.tiny:
    arch = smoke_of(base)
    seq, batch = 64, 8
else:
    # ~100M params: 12 layers, d_model 640, vocab 32k
    arch = dataclasses.replace(
        base, n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
        head_dim=64, d_ff=2048, vocab_size=32768, params_dtype="float32",
        compute_dtype="float32", name="qwen3-100m")
    seq, batch = 256, 8

mesh = make_local_mesh()
plan = plan_for_mesh(mesh)
print(f"arch={arch.name}: {arch.n_params():,} params")

with tempfile.TemporaryDirectory() as td:
    tr = Trainer(
        arch, mesh, plan,
        DataConfig(vocab_size=arch.vocab_size, seq_len=seq,
                   global_batch=batch),
        OptConfig(peak_lr=6e-4, warmup_steps=args.steps // 10,
                  total_steps=args.steps),
        TrainerConfig(num_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                      ckpt_dir=td, log_every=max(args.steps // 15, 5)),
        injector=FailureInjector(fail_at=(args.steps // 2,)))
    tr.run()
    for h in tr.history:
        print(f"step {h['step']:4d}  loss {h['loss']:7.4f}  "
              f"gnorm {h['grad_norm']:7.3f}  lr {h['lr']:.2e}  "
              f"wall {h['wall']:7.1f}s")
    print(f"survived {tr.restarts} injected failure(s); "
          f"final loss {tr.history[-1]['loss']:.4f} "
          f"(vs {tr.history[0]['loss']:.4f} at start)")
