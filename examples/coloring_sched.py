"""Coloring as a systems primitive: conflict-free microbatch scheduling.

The paper's motivating use (§1): concurrent procedures must not touch the
same resource. Here: a training batch whose samples update shared sparse
embedding rows. Coloring the sample-conflict graph yields groups that can
be applied in parallel without write conflicts — with far fewer groups
(= sync barriers) than serial execution.

Part 2 is the serving shape: a training run colors a FRESH conflict graph
every step, so the steady-state workload is a *batch of graphs*.
``schedule_many`` routes the whole batch through ``core.color_many`` —
bucketed padding, one fused program per shape bucket (DESIGN.md §8).

Run:  PYTHONPATH=src python examples/coloring_sched.py
"""
import time

import numpy as np

from repro.data.coloring_sched import (conflict_graph, schedule,
                                       schedule_many, validate_schedule)

rng = np.random.default_rng(0)
n_samples = 256


def make_batch():
    """Each sample touches 4 of 4096 embedding rows; 25% also hit one of 6
    "hot" rows (the contention that forces serialization)."""
    rows = rng.integers(6, 4096, (n_samples, 4))
    hot = rng.random(n_samples) < 0.25
    rows[hot, 0] = rng.integers(0, 6, int(hot.sum()))
    return rows


# --- one batch, one conflict graph, one schedule ---------------------------
rows = make_batch()
g = conflict_graph(rows, n_samples)
print(f"conflict graph: {n_samples} samples, {g.m} conflicting pairs, "
      f"maxdeg={g.max_degree}")

groups, n_groups, log = schedule(rows, n_samples, n_workers=4)
assert validate_schedule(rows, groups)
sizes = [len(gr) for gr in groups]
print(f"schedule: {n_groups} conflict-free groups "
      f"(vs {n_samples} fully-serial steps) — sizes {sizes}")
print(f"parallel speedup bound: {n_samples / n_groups:.1f}x, "
      f"largest group {max(sizes)} samples")

# --- many batches at once: the batched pipeline ----------------------------
batches = [make_batch() for _ in range(8)]
t0 = time.time()
results = schedule_many(batches, n_samples, n_workers=4, n_iters=1)
dt = time.time() - t0
for rows_b, (grp, ng, stats) in zip(batches, results):
    assert validate_schedule(rows_b, grp)
per_batch = [ng for _, ng, _ in results]
print(f"schedule_many: {len(batches)} conflict graphs colored in one "
      f"batched dispatch ({dt:.2f}s incl. compile) — groups per batch "
      f"{per_batch}, buckets used "
      f"{sorted({s['bucket'] for _, _, s in results})}")
