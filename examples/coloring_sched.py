"""Coloring as a systems primitive: conflict-free microbatch scheduling.

The paper's motivating use (§1): concurrent procedures must not touch the
same resource. Here: a training batch whose samples update shared sparse
embedding rows. Coloring the sample-conflict graph yields groups that can be
applied in parallel without write conflicts — with far fewer groups (= sync
barriers) than serial execution.

Run:  PYTHONPATH=src python examples/coloring_sched.py
"""
import numpy as np

from repro.data.coloring_sched import (conflict_graph, schedule,
                                       validate_schedule)

rng = np.random.default_rng(0)
n_samples = 256
# each sample touches 4 of 4096 embedding rows; 25% of samples additionally
# hit one of 6 "hot" rows (the contention that forces serialization)
rows = rng.integers(6, 4096, (n_samples, 4))
hot = rng.random(n_samples) < 0.25
rows[hot, 0] = rng.integers(0, 6, int(hot.sum()))

g = conflict_graph(rows, n_samples)
print(f"conflict graph: {n_samples} samples, {g.m} conflicting pairs, "
      f"maxdeg={g.max_degree}")

groups, n_groups, log = schedule(rows, n_samples, n_workers=4)
assert validate_schedule(rows, groups)
sizes = [len(gr) for gr in groups]
print(f"schedule: {n_groups} conflict-free groups "
      f"(vs {n_samples} fully-serial steps) — sizes {sizes}")
print(f"parallel speedup bound: {n_samples / n_groups:.1f}x, "
      f"largest group {max(sizes)} samples")
