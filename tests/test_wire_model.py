"""Measured wire bytes == modeled bytes, for every round_mask subset.

The drivers account comm volume through the exchange return value
(``stats["wire_bytes"]``); this module pins that measurement to the static
cost models — ``CommPlan.bytes_per_exchange(round_mask=...)`` for the sparse
scheme (any subset of ``ppermute`` rounds, the shape recolor's per-link
piggybacking produces) and ``allgather_bytes_per_exchange`` for the
broadcast — at halo depth 1 and 2.  Exhaustive over all 2^n_rounds subsets.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ColorConfig, RecolorConfig, partition_graph, rmat
from repro.core.comm import (AxisComm, CommConfig,
                             allgather_bytes_per_exchange, make_exchange,
                             run_sim)

P = 4


@pytest.fixture(scope="module", params=[1, 2], ids=["halo1", "halo2"])
def pgraph(request):
    return partition_graph(rmat.rmat_good(8, 8, seed=3), P,
                           halo=request.param)


def _measure(pg, scheme, round_mask):
    arrs = {k: jnp.asarray(v) for k, v in pg.arrays().items()}
    views = jnp.ones((P, pg.n_slots), jnp.int32)
    mask = None if round_mask is None else jnp.asarray(round_mask)

    def fn(a, v):
        ex = make_exchange(a, pg.n_local_max, P, AxisComm(),
                           CommConfig(scheme=scheme), pg.comm_plan.static)
        _, b = ex(v, round_mask=mask)
        return b

    out = jax.jit(lambda a, v: run_sim(fn, P, (a, v)))(arrs, views)
    out = np.asarray(out)
    assert (out == out[0]).all()          # shard-uniform by construction
    return int(out[0])


def test_sparse_wire_bytes_match_model_all_subsets(pgraph):
    plan = pgraph.comm_plan
    n_rounds = len(plan.shifts)
    assert 1 <= n_rounds <= P - 1
    assert _measure(pgraph, "sparse", None) == plan.bytes_per_exchange()
    for bits in itertools.product((False, True), repeat=n_rounds):
        want = plan.bytes_per_exchange(round_mask=bits)
        assert _measure(pgraph, "sparse", np.asarray(bits)) == want
    # depth 2 reads strictly more remote colors than depth 1
    if pgraph.halo == 2:
        pg1 = partition_graph(rmat.rmat_good(8, 8, seed=3), P, halo=1)
        assert plan.bytes_per_exchange() > pg1.comm_plan.bytes_per_exchange()


def test_allgather_wire_bytes_match_model(pgraph):
    """The broadcast ships everything regardless of any round mask."""
    model = allgather_bytes_per_exchange(P, int(pgraph.max_boundary))
    n_rounds = len(pgraph.comm_plan.shifts)
    assert _measure(pgraph, "allgather", None) == model
    for bits in itertools.product((False, True), repeat=n_rounds):
        assert _measure(pgraph, "allgather", np.asarray(bits)) == model


def test_quantized_plan_bitwise_equals_exact_plan(pgraph):
    """pow2-rung width quantization is inert (DESIGN.md §2).

    The rung plan's buffer shapes only widen; padding entries are never
    read and never counted, so (a) the exact byte model is unchanged for
    every round-mask subset, and (b) a full fused pipeline run on an
    exact-plan twin is bitwise identical — views and measured wire bytes.
    """
    import dataclasses

    from repro.core import build_comm_plan, compute_order
    from repro.core.graph import _ceil_pow2
    from repro.core.pipeline import PipelineConfig, pipeline_sim

    plan_q = pgraph.comm_plan                      # quantized by default
    plan_e = build_comm_plan(pgraph, quantize=False)
    assert plan_q.shifts == plan_e.shifts
    assert plan_q.exact_widths == plan_e.exact_widths == plan_e.widths
    assert plan_q.widths == tuple(_ceil_pow2(w) for w in plan_e.widths)
    n_rounds = len(plan_q.shifts)
    for bits in itertools.product((False, True), repeat=n_rounds):
        assert (plan_q.bytes_per_exchange(round_mask=bits)
                == plan_e.bytes_per_exchange(round_mask=bits))
    # only the padded accounting sees the rung waste
    assert (plan_q.bytes_per_exchange(padded=True)
            >= plan_e.bytes_per_exchange(padded=True)
            == plan_e.bytes_per_exchange())

    pg_e = dataclasses.replace(pgraph, quantize_plan=False)
    assert pg_e.comm_plan.widths == plan_e.widths
    cfg = PipelineConfig(
        color=ColorConfig(max_colors=64, scheme="sparse"),
        recolor=RecolorConfig(max_colors=64, scheme="sparse"),
        n_iters=2, patience=0)
    order = compute_order(pgraph, "internal_first")
    v_q, res_q = pipeline_sim(pgraph, order, cfg)
    v_e, res_e = pipeline_sim(pg_e, order, cfg)
    np.testing.assert_array_equal(np.asarray(v_q), np.asarray(v_e))
    assert res_q["color"]["wire_bytes"] == res_e["color"]["wire_bytes"]
    assert res_q["history"] == res_e["history"]    # every stat, bitwise


def test_default_scheme_follows_env(exchange_scheme):
    """The CI matrix knob: config defaults track $REPRO_SCHEME."""
    assert ColorConfig().scheme == exchange_scheme
    assert RecolorConfig().scheme == exchange_scheme
    assert CommConfig().scheme == exchange_scheme
