"""The id-width policy (repro.core.graph.id_policy) and its int32 guard.

Pure shape arithmetic at the exact ``n_local_max * maxd`` boundary — no
8GB allocations.  ``check_int32_limits`` (the historical hard guard) must
keep raising exactly where it always did; ``id_policy`` must *promote* to
int64 past the same boundaries instead.  A spy test pins that
``partition_graph`` actually consults the policy before building the ELL
arrays.
"""
import numpy as np
import pytest

from repro.core import partition_graph, rmat
from repro.core.graph import (INT32_LIMIT, INT64_LIMIT, check_int32_limits,
                              id_policy)


class TestInt32Limits:
    def test_ell_boundary_exact(self):
        # largest legal ELL tile: n_local_max * maxd == 2**31 - 1
        check_int32_limits(10, INT32_LIMIT - 1, 1)
        with pytest.raises(ValueError, match="int32 ELL overflow"):
            check_int32_limits(10, INT32_LIMIT, 1)
        # the product overflows, not either factor
        check_int32_limits(10, 2**16 - 1, 2**15 - 1)
        with pytest.raises(ValueError, match="partition over more workers"):
            check_int32_limits(10, 2**16, 2**15)

    def test_maxd2_participates(self):
        check_int32_limits(10, 2**16, 2, maxd2=2**14)
        with pytest.raises(ValueError, match="int32 ELL overflow"):
            check_int32_limits(10, 2**16, 2, maxd2=2**15)

    def test_global_id_limit(self):
        check_int32_limits(INT32_LIMIT - 1, 4, 4)
        with pytest.raises(ValueError, match="int32"):
            check_int32_limits(INT32_LIMIT, 4, 4)


class TestIdPolicyPromotion:
    """Past the guard the policy promotes instead of raising (DESIGN §10)."""

    def test_id_dtype_boundary(self):
        # just below the int32 vertex bound: everything stays int32
        pol = id_policy(INT32_LIMIT - 1, 4, 4)
        assert np.dtype(pol.id_dtype) == np.int32
        assert not pol.promoted and pol.id_itemsize == 4
        # at/above the bound: global ids promote, ELL untouched
        pol = id_policy(INT32_LIMIT, 4, 4)
        assert np.dtype(pol.id_dtype) == np.int64
        assert np.dtype(pol.ell_dtype) == np.int32
        assert pol.promoted and pol.id_itemsize == 8

    def test_ell_dtype_boundary(self):
        pol = id_policy(10, INT32_LIMIT - 1, 1)
        assert np.dtype(pol.ell_dtype) == np.int32 and not pol.promoted
        pol = id_policy(10, INT32_LIMIT, 1)
        assert np.dtype(pol.ell_dtype) == np.int64
        assert np.dtype(pol.id_dtype) == np.int32   # ids independent
        assert pol.promoted

    def test_maxd2_widens_ell(self):
        pol = id_policy(10, 2**16, 2, 2**15)
        assert np.dtype(pol.ell_dtype) == np.int64

    def test_allow_int64_false_is_the_hard_guard(self):
        with pytest.raises(ValueError, match="int32"):
            id_policy(INT32_LIMIT, 4, 4, allow_int64=False)
        with pytest.raises(ValueError, match="int32 ELL overflow"):
            id_policy(10, INT32_LIMIT, 1, allow_int64=False)

    def test_int64_ceiling_always_raises(self):
        with pytest.raises(ValueError, match="int64"):
            id_policy(INT64_LIMIT, 4, 4)
        with pytest.raises(ValueError, match="int64"):
            id_policy(10, INT64_LIMIT // 2, 4)

    def test_partition_dtypes_follow_policy_at_cpu_scale(self):
        g = rmat.grid2d(4, 4, 5)
        pg = partition_graph(g, 2)
        assert pg.gvid.dtype == np.int32 and pg.prio.dtype == np.int32
        assert g.indices.dtype == np.int32


class TestPartitionRunsThePolicy:
    def test_partition_graph_runs_the_guard(self, monkeypatch):
        from repro.core import graph as graph_mod
        calls = []
        real = id_policy

        def spy(*a, **k):
            calls.append((a, k))
            return real(*a, **k)

        monkeypatch.setattr(graph_mod, "id_policy", spy)
        g = rmat.grid2d(4, 4, 5)
        partition_graph(g, 2)
        assert calls, "partition_graph must consult the id policy"
        # every call site reasons about this graph's global id range, and
        # the ELL-guard site passes a real tile (n_local_max * maxd > 1)
        for a, _ in calls:
            assert a[0] == g.n
        assert any(a[1] * a[2] > 1 for a, _ in calls if len(a) >= 3)
