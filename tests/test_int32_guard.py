"""The int32-CSR guard (repro.core.graph.check_int32_limits).

Pure shape arithmetic at the exact ``n_local_max * maxd`` boundary — no
8GB allocations — plus a spy test that ``partition_graph`` actually runs
the guard before building the ELL arrays.
"""
import pytest

from repro.core import partition_graph, rmat
from repro.core.graph import INT32_LIMIT, check_int32_limits


class TestInt32Limits:
    def test_ell_boundary_exact(self):
        # largest legal ELL tile: n_local_max * maxd == 2**31 - 1
        check_int32_limits(10, INT32_LIMIT - 1, 1)
        with pytest.raises(ValueError, match="int32 ELL overflow"):
            check_int32_limits(10, INT32_LIMIT, 1)
        # the product overflows, not either factor
        check_int32_limits(10, 2**16 - 1, 2**15 - 1)
        with pytest.raises(ValueError, match="partition over more workers"):
            check_int32_limits(10, 2**16, 2**15)

    def test_maxd2_participates(self):
        check_int32_limits(10, 2**16, 2, maxd2=2**14)
        with pytest.raises(ValueError, match="int32 ELL overflow"):
            check_int32_limits(10, 2**16, 2, maxd2=2**15)

    def test_global_id_limit(self):
        check_int32_limits(INT32_LIMIT - 1, 4, 4)
        with pytest.raises(ValueError, match="int32"):
            check_int32_limits(INT32_LIMIT, 4, 4)

    def test_partition_graph_runs_the_guard(self, monkeypatch):
        from repro.core import graph as graph_mod
        calls = []

        def spy(*a, **k):
            calls.append((a, k))
            return check_int32_limits(*a, **k)

        monkeypatch.setattr(graph_mod, "check_int32_limits", spy)
        g = rmat.grid2d(4, 4, 5)
        partition_graph(g, 2)
        assert calls, "partition_graph must invoke the int32 guard"
        (n_global, n_local_max, maxd), _ = calls[0]
        assert n_global == g.n and n_local_max * maxd < INT32_LIMIT
