"""Batched multi-graph pipeline (ISSUE 5): ``color_many`` == solo fused runs.

The acceptance property: each graph of a batch — padded into its shape
bucket and run on the bucket's shared (union) sparse round schedule — must
be *bitwise identical*, view and every per-iteration stat including
measured ``wire_bytes``, to a solo ``pipeline_sim`` run of the same padded
member under its own comm plan with the same per-graph keys.  Swept across
bucket boundaries, both exchange schemes, distance 1 and 2, randomized
selection, and the per-graph adaptive stop (lanes stopping at different
iterations inside one vmapped ``lax.while_loop``).
"""
import jax
import numpy as np
import pytest

from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                        bucket_graphs, check_coloring, color_many,
                        compute_order, ordering, pad_partition,
                        partition_graph, pipeline_sim, rmat)
from repro.launch.serve_coloring import (ColoringService, FakeClock,
                                         ServeConfig, default_config)

MC = 512


def _mix():
    """Four small graphs that land in >= 2 shape buckets."""
    return [rmat.rmat_good(6, 8, seed=1), rmat.rmat_bad(6, 8, seed=2),
            rmat.rmat_good(8, 8, seed=3), rmat.grid2d(16, 16, 9)]


def _solo_keys(cfg, gi):
    """The folded per-graph default streams of ``color_many``."""
    return (jax.random.fold_in(jax.random.key(cfg.color.seed), gi),
            jax.random.fold_in(jax.random.key(cfg.seed), gi))


def _assert_matches_solo(pgs, cfg, res, order_kind):
    """Every batch lane == pipeline_sim on its padded member (own plan)."""
    for bucket in bucket_graphs(pgs):
        for j, gi in enumerate(bucket.indices):
            m = bucket.members[j]
            ck, rk = _solo_keys(cfg, gi)
            v, solo = pipeline_sim(m, compute_order(m, order_kind), cfg,
                                   color_key=ck, recolor_key=rk)
            np.testing.assert_array_equal(res[gi]["view"], np.asarray(v))
            assert res[gi]["history"] == solo["history"]
            assert res[gi]["color"] == solo["color"]
            assert res[gi]["n_iters_run"] == solo["n_iters_run"]


@pytest.mark.parametrize("P,scheme", [(4, "sparse"), (2, "allgather")])
def test_color_many_bitwise_matches_solo(P, scheme):
    """Across bucket boundaries + the union round schedule, both schemes."""
    graphs = _mix()
    pgs = [partition_graph(g, P) for g in graphs]
    assert len(bucket_graphs(pgs)) >= 2          # really spans buckets
    cfg = PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=64, scheme=scheme,
                          selection="random_x", random_x=10),
        recolor=RecolorConfig(max_colors=MC, scheme=scheme),
        n_iters=3, base_perm="nd", rand_every=2)
    res = color_many(pgs, cfg, orders=ordering.NATURAL)
    for g, r in zip(graphs, res):
        st = check_coloring(g, r["colors"])
        assert st["valid"], st
        assert st["n_colors"] == r["history"][-1]["n_colors_distinct"]
    _assert_matches_solo(pgs, cfg, res, ordering.NATURAL)


def test_color_many_d2_two_hop_halo():
    """Distance-2 batch over halo=2 partitions matches the solo pipeline."""
    graphs = [rmat.grid2d(12, 12, 9), rmat.grid2d(16, 12, 9)]
    pgs = [partition_graph(g, 2, halo=2) for g in graphs]
    cfg = PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=64, tile=16,
                          max_rounds=256, distance=2),
        recolor=RecolorConfig(max_colors=MC, distance=2), n_iters=2)
    res = color_many(pgs, cfg)
    for g, r in zip(graphs, res):
        assert check_coloring(g, r["colors"], distance=2)["valid"]
    _assert_matches_solo(pgs, cfg, res, ordering.INTERNAL_FIRST)


def test_color_many_per_graph_adaptive_stop():
    """Lanes stop at different iterations; each stays a bitwise solo run
    (vmap's while_loop select-masks the body on finished lanes)."""
    pgs = [partition_graph(rmat.rmat_good(7, 8, seed=s), 4)
           for s in (1, 2, 3, 4)]
    cfg = PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=64),
        recolor=RecolorConfig(max_colors=MC),
        n_iters=12, base_perm="nd", rand_every=2, patience=1)
    res = color_many(pgs, cfg)
    iters = [r["n_iters_run"] for r in res]
    assert len(set(iters)) > 1                   # genuinely divergent stops
    assert all(it < 12 for it in iters)
    assert all(len(r["history"]) == it for r, it in zip(res, iters))
    _assert_matches_solo(pgs, cfg, res, ordering.INTERNAL_FIRST)


def test_color_many_pad_batch_lanes_dropped():
    """pow2 batch-lane padding (serving shape-stability) changes nothing."""
    pgs = [partition_graph(rmat.rmat_good(6, 8, seed=s), 2) for s in (1, 2, 3)]
    cfg = PipelineConfig(color=ColorConfig(max_colors=MC, superstep=64),
                         recolor=RecolorConfig(max_colors=MC), n_iters=2)
    a = color_many(pgs, cfg)
    b = color_many(pgs, cfg, pad_batch=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["view"], y["view"])
        np.testing.assert_array_equal(x["colors"], y["colors"])
        assert x["history"] == y["history"] and x["color"] == y["color"]


def test_pad_partition_preserves_coloring():
    """Padding every dim is inert: same colors, same stats (sparse plan
    widths are invariant to padding; First Fit is shape-independent)."""
    g = rmat.rmat_good(7, 8, seed=5)
    pg = partition_graph(g, 4)
    padded = pad_partition(
        pg, n_local_max=pg.n_local_max + 7, max_ghost=pg.max_ghost + 3,
        max_boundary=pg.max_boundary + 2, m_local_max=pg.m_local_max + 11,
        maxd=pg.maxd + 5)
    cfg = PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=64, scheme="sparse"),
        recolor=RecolorConfig(max_colors=MC, scheme="sparse"), n_iters=2)
    outs = []
    for q in (pg, padded):
        v, r = pipeline_sim(q, compute_order(q, ordering.NATURAL), cfg)
        colors = q.gather_global_colors(np.asarray(v)[:, :q.n_local_max])
        outs.append((colors, r))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    assert outs[0][1] == outs[1][1]              # histories + color stats
    assert pad_partition(pg) is pg               # no-op fast path


def test_bucket_graphs_partitions_input():
    pgs = [partition_graph(g, 2) for g in _mix()]
    buckets = bucket_graphs(pgs)
    covered = sorted(i for b in buckets for i in b.indices)
    assert covered == list(range(len(pgs)))
    for b in buckets:
        dims = {(m.n_local_max, m.maxd, m.max_ghost, m.max_boundary,
                 m.m_local_max) for m in b.members}
        assert len(dims) == 1                    # stackable shapes
    # exact-match mode groups only identical dims
    exact = bucket_graphs(pgs, round_pow2=False)
    assert len(exact) >= len(buckets)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_batched_leading_dim_matches_loop(rng, backend):
    """(B, V, MAXD) kernel inputs == per-graph loop, both backends (the
    batched pipeline's multi-graph tiles flatten onto the row/grid axis)."""
    from repro.kernels import ops
    b, v, d, mc = 3, 37, 9, 64
    nbr = rng.integers(-2, mc + 8, (b, v, d)).astype(np.int32)
    nbr2 = rng.integers(-2, mc + 8, (b, v, 5)).astype(np.int32)
    active = rng.random((b, v)) < 0.85
    rand = rng.integers(0, 2**32, (b, v), dtype=np.uint32)
    myc = rng.integers(0, mc, (b, v)).astype(np.int32)
    myp = rng.integers(0, 10_000, (b, v)).astype(np.int32)
    nbrp = rng.integers(0, 10_000, (b, v, d)).astype(np.int32)
    nbr2p = rng.integers(0, 10_000, (b, v, 5)).astype(np.int32)
    kw = dict(backend=backend, interpret=None if backend == "xla" else True)

    got = ops.select_colors(nbr, active, rand, max_colors=mc,
                            selection=ops.RANDOM_X, x=5, **kw)
    got2 = ops.select_colors_d2(nbr, nbr2, active, max_colors=mc, **kw)
    conf = ops.detect_conflicts(myc, myp, nbr, nbrp, active, **kw)
    conf2 = ops.detect_conflicts_d2(myc, myp, nbr, nbrp, nbr2, nbr2p,
                                    active, **kw)
    assert got.shape == (b, v) and conf.shape == (b, v)
    for i in range(b):
        np.testing.assert_array_equal(
            np.asarray(got[i]),
            np.asarray(ops.select_colors(nbr[i], active[i], rand[i],
                                         max_colors=mc,
                                         selection=ops.RANDOM_X, x=5, **kw)))
        np.testing.assert_array_equal(
            np.asarray(got2[i]),
            np.asarray(ops.select_colors_d2(nbr[i], nbr2[i], active[i],
                                            max_colors=mc, **kw)))
        np.testing.assert_array_equal(
            np.asarray(conf[i]),
            np.asarray(ops.detect_conflicts(myc[i], myp[i], nbr[i], nbrp[i],
                                            active[i], **kw)))
        np.testing.assert_array_equal(
            np.asarray(conf2[i]),
            np.asarray(ops.detect_conflicts_d2(myc[i], myp[i], nbr[i],
                                               nbrp[i], nbr2[i], nbr2p[i],
                                               active[i], **kw)))


def test_coloring_service_round_trip():
    """Submit/flush returns valid colorings keyed by request id."""
    svc = ColoringService(
        P=2, validate=True,
        cfg=default_config(max_colors=MC, n_iters=2, patience=0))
    graphs = _mix()
    ids = [svc.submit(g) for g in graphs]
    assert svc.pending == len(graphs)
    res = svc.flush()
    assert svc.pending == 0 and sorted(res) == sorted(ids)
    for g, i in zip(graphs, ids):
        assert res[i]["check"]["valid"]
        assert res[i]["n_colors"] == res[i]["check"]["n_colors"]


@pytest.mark.parametrize("mode", ["flush", "continuous"])
def test_service_stats_counters_consistent(mode):
    """Regression (ISSUE 10 satellite): ``stats()`` always reports the
    shed/deferral counters, ``pending`` == queued + running in every
    state, and completions-by-route sum to the results returned."""
    svc = ColoringService(
        P=2, validate=True, clock=FakeClock(),
        cfg=default_config(max_colors=MC, n_iters=2, patience=0),
        serve=ServeConfig(mode=mode, lanes=2, max_queue=3))
    st = svc.stats()
    for key in ("n_shed", "n_deferred", "n_failed", "solo", "batch",
                "lane", "queued", "running", "engines"):
        assert key in st, key
    assert st["queued"] == st["running"] == svc.pending == 0
    graphs = _mix()
    ids = [svc.submit(g) for g in graphs]
    st = svc.stats()
    assert st["queued"] + st["running"] == svc.pending
    # continuous mode sheds the submit past max_queue; flush never sheds
    n_shed = st["n_shed"]
    assert n_shed == (len(graphs) - 3 if mode == "continuous" else 0)
    assert svc.pending == len(graphs) - n_shed
    res = svc.flush()
    st = svc.stats()
    assert svc.pending == st["queued"] == st["running"] == 0
    assert len(res) == len(graphs) - n_shed
    assert st["solo"] + st["batch"] + st["lane"] == len(res)
    assert st["n_shed"] == n_shed and st["n_failed"] == 0
    for i in ids[:len(graphs) - n_shed]:
        assert res[i]["check"]["valid"]
