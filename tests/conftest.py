"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device mesh is exercised only via repro.launch.dryrun and the
subprocess-based tests below).

The suite runs under a ``scheme={sparse,allgather,auto}`` CI matrix: setting
``REPRO_SCHEME`` flips the *default* boundary-exchange scheme of every config
(see ``repro.core.comm.DEFAULT_SCHEME``), so each push exercises both
exchange paths end-to-end plus the trace-time auto decision.  Colorings are
bitwise-identical across schemes, which is exactly why all golden pins must
hold under any value.
"""
import os

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def exchange_scheme():
    """The active default boundary-exchange scheme (env-driven CI matrix)."""
    from repro.core import comm
    return comm.DEFAULT_SCHEME


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    scheme = os.environ.get("REPRO_SCHEME")
    if scheme is not None and scheme not in ("sparse", "allgather", "auto"):
        raise pytest.UsageError(
            f"REPRO_SCHEME={scheme!r} invalid, want sparse|allgather|auto")


def pytest_report_header(config):
    return f"repro exchange scheme: {os.environ.get('REPRO_SCHEME', 'auto')}"
