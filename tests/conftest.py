"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device mesh is exercised only via repro.launch.dryrun and the
subprocess-based tests below)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
