"""Speculative coloring + iterative recoloring: the paper's invariants."""
import jax
import numpy as np
import pytest

from repro.core import (ColorConfig, RecolorConfig, arc_sim, assert_valid,
                        check_coloring, color_graph_sim, colors_from_views,
                        compute_order, ordering, partition_graph,
                        recolor_iterations, recolor_sim, rmat, selection)

GRAPHS = {
    "grid9": lambda: rmat.grid2d(32, 32, 9),
    "rmat_good": lambda: rmat.rmat_good(10, 8, seed=3),
}


def color(g, P, *, order_kind=ordering.NATURAL, sel=selection.FIRST_FIT,
          superstep=64, x=10, max_colors=512, seed=0):
    pg = partition_graph(g, P)
    order = compute_order(pg, order_kind)
    cfg = ColorConfig(max_colors=max_colors, superstep=superstep,
                      selection=sel, random_x=x, seed=seed)
    view, stats = color_graph_sim(pg, order, cfg)
    return pg, np.asarray(view), stats


@pytest.mark.parametrize("gname", list(GRAPHS))
@pytest.mark.parametrize("P", [1, 4, 8])
def test_speculative_valid(gname, P):
    g = GRAPHS[gname]()
    pg, view, stats = color(g, P)
    colors = colors_from_views(pg, view)
    st = assert_valid(g, colors)
    assert st["n_colors"] == stats["n_colors"]
    assert st["n_colors"] <= g.max_degree + 1  # greedy bound (Alg. 1)


@pytest.mark.parametrize("sel", [selection.FIRST_FIT, selection.STAGGERED,
                                 selection.LEAST_USED, selection.RANDOM_X])
def test_selection_strategies_valid(sel):
    g = GRAPHS["rmat_good"]()
    pg, view, _ = color(g, 4, sel=sel)
    assert_valid(g, colors_from_views(pg, view), what=sel)


@pytest.mark.parametrize("order_kind", ordering.ALL_ORDERINGS)
def test_orderings_valid(order_kind):
    g = GRAPHS["grid9"]()
    pg, view, _ = color(g, 4, order_kind=order_kind)
    assert_valid(g, colors_from_views(pg, view), what=order_kind)


def test_sl_beats_natural_sequentially():
    """Table 2's expectation: SL/LF <= NAT colors on RMAT graphs (P=1).

    (On perfectly regular grids SL can lose to NAT — verified identical to
    networkx's smallest_last — so the check uses the skewed-degree suite.)"""
    g = rmat.rmat_bad(10, 8, seed=2)
    _, _, s_nat = color(g, 1, order_kind=ordering.NATURAL, max_colors=2048)
    _, _, s_lf = color(g, 1, order_kind=ordering.LARGEST_FIRST,
                       max_colors=2048)
    _, _, s_sl = color(g, 1, order_kind=ordering.SMALLEST_LAST,
                       max_colors=2048)
    assert s_lf["n_colors"] <= s_nat["n_colors"]
    assert s_sl["n_colors"] <= s_nat["n_colors"]


def test_randomx_fewer_rounds_more_colors():
    """§3.2: Random-X reduces conflicts (rounds) but costs colors."""
    g = rmat.rmat_good(11, 8, seed=5)
    _, _, s_ff = color(g, 8, sel=selection.FIRST_FIT, superstep=256)
    _, _, s_rx = color(g, 8, sel=selection.RANDOM_X, x=50, superstep=256)
    assert s_rx["n_colors"] >= s_ff["n_colors"]
    assert s_rx["n_rounds"] <= s_ff["n_rounds"] + 1


class TestRecolor:
    def setup_method(self, _):
        self.g = GRAPHS["rmat_good"]()
        self.pg, self.view, self.stats = color(self.g, 4)
        self.rcfg = RecolorConfig(max_colors=512)

    @pytest.mark.parametrize("perm", ["rv", "ni", "nd", "rand"])
    def test_permutations_valid_and_no_worse(self, perm):
        new_view, st = recolor_sim(self.pg, self.view, perm, self.rcfg)
        colors = colors_from_views(self.pg, np.asarray(new_view))
        assert_valid(self.g, colors, what=f"RC-{perm}")
        # Culberson: recoloring never increases the number of colors
        assert st["n_colors"] <= self.stats["n_colors"]

    def test_multiple_iterations_monotone(self):
        view, hist = recolor_iterations(self.pg, self.view, 8, self.rcfg,
                                        base_perm="nd")
        cs = [h["n_colors"] for h in hist]
        assert all(a >= b for a, b in zip(cs, cs[1:]))
        assert_valid(self.g, colors_from_views(self.pg, np.asarray(view)))

    def test_distributed_equals_sequential(self):
        """§3: RC in distributed memory == sequential RC (same seed)."""
        c_global = colors_from_views(self.pg, self.view)
        pg1 = partition_graph(self.g, 1)
        v1 = np.zeros((1, pg1.n_slots), np.int32)
        v1[0, :pg1.n_local_max] = c_global
        key = jax.random.key(11)
        v1n, st1 = recolor_sim(pg1, v1, "nd", self.rcfg, key=key)
        vPn, stP = recolor_sim(self.pg, self.view, "nd", self.rcfg, key=key)
        assert (colors_from_views(pg1, np.asarray(v1n))
                == colors_from_views(self.pg, np.asarray(vPn))).all()

    def test_piggyback_equals_per_step_exchange(self):
        """Coalesced exchanges produce the identical coloring (§3.1)."""
        key = jax.random.key(3)
        v_pig, st_pig = recolor_sim(self.pg, self.view, "nd",
                                    RecolorConfig(max_colors=512,
                                                  piggyback=True), key=key)
        v_all, st_all = recolor_sim(self.pg, self.view, "nd",
                                    RecolorConfig(max_colors=512,
                                                  piggyback=False), key=key)
        assert (np.asarray(v_pig) == np.asarray(v_all)).all()
        assert st_pig["n_exchanges"] <= st_all["n_exchanges"]

    def test_arc_valid(self):
        view, st = arc_sim(self.pg, self.view, "nd", self.rcfg,
                           ColorConfig(max_colors=512, superstep=64))
        assert_valid(self.g, colors_from_views(self.pg, np.asarray(view)),
                     what="aRC")


def test_exchange_staleness_still_valid():
    """Asynchronous-style (stale ghosts) coloring converges to valid."""
    g = GRAPHS["rmat_good"]()
    pg = partition_graph(g, 8)
    order = compute_order(pg, ordering.NATURAL)
    cfg = ColorConfig(max_colors=512, superstep=64, exchange_every=4)
    view, stats = color_graph_sim(pg, order, cfg)
    assert_valid(g, colors_from_views(pg, np.asarray(view)))
