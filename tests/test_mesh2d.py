"""The 2D ``batch × shard`` mesh layer (DESIGN.md §10).

In-process: the ``MeshSpec`` layouts, the ``shard_axis_of`` axis-name
contract (stub meshes — no devices needed), the ``PlanSignature.axes``
cache-key component, and the lane-target padding arithmetic.

Subprocess (8 host devices, ``@slow``): the ISSUE's bitwise pins —
``color_many_sharded`` on a 2D mesh with batch=1 equals the 1-axis result
equals ``pipeline_sim``/``color_many``, both exchange schemes, distance 1
and 2, plus a genuinely-sharded batch case on a ``(2, 2)`` mesh.
"""
import dataclasses

import pytest

from repro.core.comm import (AXIS, BATCH_AXIS, batch_axis_of, batch_axis_size,
                             shard_axis_of)
from repro.core.pipeline import _lane_target
from repro.launch.mesh import MeshSpec

from test_sharded_subprocess import run_sub


@dataclasses.dataclass
class _StubMesh:
    """Just enough mesh surface for the axis-name contract functions."""
    axis_names: tuple
    sizes: tuple

    @property
    def shape(self):
        return dict(zip(self.axis_names, self.sizes))


class TestMeshSpec:
    def test_layouts(self):
        assert MeshSpec.worker(8) == MeshSpec((8,), (AXIS,))
        assert MeshSpec.coloring(4, 2) == MeshSpec((2, 4), (BATCH_AXIS, AXIS))
        assert MeshSpec.coloring(4) == MeshSpec((1, 4), (BATCH_AXIS, AXIS))
        assert MeshSpec.production().axes == ("data", "model")
        assert MeshSpec.production(multi_pod=True).shape == (2, 16, 16)
        assert MeshSpec.local().shape == (1, 1)
        assert MeshSpec.coloring(4, 2).n_devices == 8

    def test_shape_axes_must_agree(self):
        with pytest.raises(AssertionError):
            MeshSpec((2, 4), ("workers",))

    def test_local_build_smoke(self):
        # in-process: only 1 device, but the degenerate meshes build
        mesh = MeshSpec.local().build()
        assert shard_axis_of(mesh) == "model"      # all-size-1 fallback
        assert batch_axis_of(mesh) is None
        assert batch_axis_size(mesh) == 1
        mesh1 = MeshSpec.coloring(1, 1).build()
        assert shard_axis_of(mesh1) == AXIS
        assert batch_axis_size(mesh1) == 1


class TestShardAxisContract:
    def test_workers_always_wins(self):
        m = _StubMesh((BATCH_AXIS, AXIS), (2, 4))
        assert shard_axis_of(m) == AXIS
        assert batch_axis_of(m) == BATCH_AXIS
        assert batch_axis_size(m) == 2

    def test_single_non_batch_axis(self):
        assert shard_axis_of(_StubMesh(("shards",), (8,))) == "shards"
        assert shard_axis_of(_StubMesh((BATCH_AXIS, "s"), (2, 8))) == "s"

    def test_single_sized_axis(self):
        assert shard_axis_of(_StubMesh(("data", "model"), (1, 8))) == "model"
        assert shard_axis_of(_StubMesh(("data", "model"), (8, 1))) == "data"

    def test_all_size_one_smoke_mesh(self):
        assert shard_axis_of(_StubMesh(("data", "model"), (1, 1))) == "model"

    def test_ambiguous_mesh_raises(self):
        with pytest.raises(ValueError, match="MeshSpec"):
            shard_axis_of(_StubMesh(("data", "model"), (2, 4)))


class TestSignatureAxes:
    def test_sim_signature_pins_the_vmap_axis(self):
        from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                                partition_graph, plan_signature, rmat)
        pg = partition_graph(rmat.grid2d(8, 8, 5), 4)
        cfg = PipelineConfig(
            color=ColorConfig(max_colors=32, scheme="allgather"),
            recolor=RecolorConfig(max_colors=32, scheme="allgather"))
        sig = plan_signature(pg, cfg)
        assert sig.axes == ((AXIS, 4),)
        assert f"axes={AXIS}=4" in sig.describe()

    def test_mesh_signature_pins_the_mesh_geometry(self):
        from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                                partition_graph, plan_signature, rmat)
        pg = partition_graph(rmat.grid2d(8, 8, 5), 1)
        cfg = PipelineConfig(
            color=ColorConfig(max_colors=32, scheme="allgather"),
            recolor=RecolorConfig(max_colors=32, scheme="allgather"))
        mesh = MeshSpec.coloring(1, 1).build()
        sig = plan_signature(pg, cfg, mesh=mesh)
        assert sig.axes == ((BATCH_AXIS, 1), (AXIS, 1))
        # a different geometry is a different program identity
        assert sig != plan_signature(pg, cfg)


class TestLaneTarget:
    def test_pow2_padding(self):
        assert _lane_target(3, True) == 4
        assert _lane_target(4, True) == 4
        assert _lane_target(5, True) == 8
        assert _lane_target(3, False) == 3

    def test_batch_axis_divisibility(self):
        assert _lane_target(1, True, 2) == 2
        assert _lane_target(3, True, 4) == 4
        assert _lane_target(3, False, 2) == 4
        assert _lane_target(4, True, 2) == 4


@pytest.mark.slow
def test_mesh2d_batch1_bitwise_equals_1axis_and_sim():
    """The ISSUE's safety pin: 2D mesh (batch=1) == 1-axis == pipeline_sim,
    both schemes, distance 1 and 2."""
    print(run_sub("""
        import numpy as np
        from repro.core import (rmat, partition_graph, compute_order,
                                ColorConfig, RecolorConfig, PipelineConfig,
                                color_many, color_many_sharded, pipeline_sim,
                                pipeline_sharded)
        from repro.launch.mesh import make_coloring_mesh, make_worker_mesh
        P = 4
        mesh1 = make_worker_mesh(P)
        mesh2 = make_coloring_mesh(P, batch=1)
        assert tuple(mesh2.axis_names) == ("batch", "workers")
        for scheme, distance in (("sparse", 1), ("allgather", 1),
                                 ("sparse", 2)):
            halo = 2 if distance == 2 else 1
            gs = [rmat.rmat_good(6, 8, seed=3), rmat.grid2d(16, 16, 9)]
            pgs = [partition_graph(g, P, halo=halo) for g in gs]
            cfg = PipelineConfig(
                color=ColorConfig(max_colors=64, superstep=64, scheme=scheme,
                                  distance=distance),
                recolor=RecolorConfig(max_colors=64, scheme=scheme,
                                      distance=distance),
                n_iters=2, patience=1)
            sim = color_many(pgs, cfg, pad_batch=True)
            one = color_many_sharded(pgs, cfg, mesh1, pad_batch=True)
            two = color_many_sharded(pgs, cfg, mesh2, pad_batch=True)
            for a, b, c in zip(sim, one, two):
                assert np.array_equal(a["view"], b["view"])
                assert np.array_equal(a["view"], c["view"])
                assert np.array_equal(a["colors"], c["colors"])
                assert a["history"] == b["history"] == c["history"]
                assert a["color"] == b["color"] == c["color"]
            # solo fused pipeline on the 2D mesh == sim
            order = compute_order(pgs[0], "internal_first")
            v_sim, r_sim = pipeline_sim(pgs[0], order, cfg)
            v_2d, r_2d = pipeline_sharded(pgs[0], order, cfg, mesh2)
            assert np.array_equal(np.asarray(v_sim), np.asarray(v_2d))
            assert r_sim == r_2d
            print("pin OK:", scheme, "D", distance)
        print("mesh2d batch=1 bitwise pins OK")
    """))


@pytest.mark.slow
def test_mesh2d_sharded_batch_on_2x2_mesh():
    """(2, 2) mesh: 2 shards × 2 batch lanes per device group — lanes are
    genuinely sharded over the batch axis and results still match sim."""
    print(run_sub("""
        import numpy as np
        from repro.core import (rmat, partition_graph, ColorConfig,
                                RecolorConfig, PipelineConfig, color_many,
                                color_many_sharded)
        from repro.launch.mesh import make_coloring_mesh
        P = 2
        mesh = make_coloring_mesh(P, batch=2)
        assert mesh.devices.shape == (2, 2)
        gs = [rmat.rmat_er(6, 8, seed=s) for s in (1, 2, 3)]
        pgs = [partition_graph(g, P) for g in gs]
        cfg = PipelineConfig(
            color=ColorConfig(max_colors=64, superstep=64, scheme="sparse"),
            recolor=RecolorConfig(max_colors=64, scheme="sparse"),
            n_iters=2, patience=1)
        sim = color_many(pgs, cfg, pad_batch=True)
        sh = color_many_sharded(pgs, cfg, mesh, pad_batch=True)
        for a, b in zip(sim, sh):
            assert np.array_equal(a["view"], b["view"])
            assert np.array_equal(a["colors"], b["colors"])
            assert a["history"] == b["history"] and a["color"] == b["color"]
            assert a["n_iters_run"] == b["n_iters_run"]
        print("(2,2) mesh sharded-batch OK")
    """))
