"""NEGATIVE id-overflow fixtures: nothing here may fire."""
import numpy as np


def promoted_packing(u, v, n):
    return u.astype(np.int64) * n + v       # explicit 64-bit promotion


def promoted_call(u, v, n):
    return np.int64(u) * n + v


def promoted_dtype_kw(v, n, m):
    base = np.arange(m, dtype=np.int64)
    return base * n + v.astype(np.int64)


def size_by_size(n_local_max, maxd, n):
    return n_local_max * maxd + n           # sizes only, no id operand


def plain_sum(u, v):
    return u + v                            # no multiplicative packing


def policy_packing(u, v, n, pol):
    return u.astype(pol.id_dtype) * n + v   # id_policy picks the width


def policy_ell_packing(row, stride, idx, pol):
    return row.astype(pol.ell_dtype) * stride + idx
