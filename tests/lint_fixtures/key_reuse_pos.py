"""POSITIVE key-reuse fixtures: every marked line must fire."""
import jax


def linear_reuse(key):
    a = jax.random.uniform(key, (4,))
    b = jax.random.normal(key, (4,))        # FIRE: key consumed twice
    return a + b


def loop_reuse(key, n):
    out = 0.0
    for _ in range(n):
        out += jax.random.uniform(key, ())  # FIRE: same key every iteration
    return out


def reuse_after_tracking():
    key = jax.random.PRNGKey(0)
    x = jax.random.bits(key, (2,))
    y = jax.random.permutation(key, 8)      # FIRE: replayed local key
    return x, y
