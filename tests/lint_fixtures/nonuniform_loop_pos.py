"""POSITIVE nonuniform-loop fixtures: every marked site must fire."""
import jax
import jax.numpy as jnp


def python_loop_traced_spmd(view):
    n = jnp.sum(view > 0)
    acc = 0
    for i in range(n):                      # FIRE: traced python loop bound
        acc = acc + i
    return acc


def while_nonuniform_spmd(view, comm):
    def cond(c):
        return jnp.any(c > 0)               # per-shard: shards may disagree

    def body(c):
        return c - comm.psum(c)

    return jax.lax.while_loop(cond, body, view)  # FIRE: divergent trip count


def fori_nonuniform_spmd(view, comm):
    n_need = jnp.sum(view > 0)              # per-shard count, never reduced

    def body(i, c):
        return comm.psum(c)

    return jax.lax.fori_loop(0, n_need, body, view)  # FIRE: divergent bound
