"""POSITIVE host-sync fixtures (linted under a virtual core/ path)."""
import jax
import jax.numpy as jnp
import numpy as np


def leaky_spmd(view, comm):
    total = jnp.sum(view)
    n = int(total)                          # FIRE: traced -> python int
    host = np.asarray(view)                 # FIRE: device -> host transfer
    return n, host


def loop_body_sync_spmd(view):
    def body(i, acc):
        return acc + view[i].item()         # FIRE: .item() inside fori body
    return jax.lax.fori_loop(0, 4, body, 0.0)
