"""NEGATIVE divergent-collective fixtures: nothing here may fire."""
import jax
import jax.numpy as jnp

from repro.core.comm import shard_uniform


def pmax_gated_exchange_spmd(view, comm):
    # predicate is a collective reduction: every shard agrees
    pending = comm.pmax(jnp.any(view > 0))
    ex = lambda v: comm.psum(v)
    return jax.lax.cond(pending, ex, lambda v: v, view)


def contract_gated_exchange_spmd(view, round_mask, comm):
    # uniformity asserted by contract at the consumption site
    round_mask = shard_uniform(round_mask)
    ex = lambda v: comm.psum(v)
    return jax.lax.cond(round_mask[0], ex, lambda v: v, view)


def divergent_pure_branch_spmd(view, comm):
    # divergent predicate but no collective under it: allowed
    mine = comm.index() == 0
    return jax.lax.cond(mine, lambda v: v + 1, lambda v: v, view)


def static_python_branch_spmd(view, cfg: "RecolorConfig", comm):
    # python branch on a static config value around a collective: allowed
    if cfg.use_psum:
        view = comm.psum(view)
    return view
