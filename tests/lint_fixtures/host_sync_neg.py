"""NEGATIVE host-sync fixtures (linted under a virtual core/ path)."""
import jax.numpy as jnp
import numpy as np


def stats_to_host(stats):
    # the one blessed exit: this function IS the host boundary
    return {k: int(jnp.max(v)) for k, v in stats.items()}


def static_shapes_spmd(view, arrs):
    n_local_max = int(view.shape[0])        # trace-time constant: fine
    width = len(arrs)                       # python size: fine
    return jnp.zeros((n_local_max, width))


def host_driver(pg, cfg):
    # not device code (no _spmd suffix, nothing handed to lax): a driver
    # may sync freely once the device program has returned
    out = np.asarray(pg)
    return int(out.max())
