"""POSITIVE id-overflow fixtures: every marked line must fire."""
import numpy as np


def packed_dedup_key(u, v, n):
    return u * n + v                        # FIRE: PR 3's exact bug


def grid_vertex_id(ii, jj, cols):
    vid = ii * cols + jj                    # FIRE: unpromoted 2D packing
    return vid


def grid3d_vertex_id(ii, jj, kk, ny, nz):
    return ii * ny * nz + jj * nz + kk      # FIRE: nested 3D packing


def cell_key(cid, grid_n):
    return cid[:, 0] * grid_n + cid[:, 1]   # FIRE: subscripted id operands


def policy_bypassed(u, v, n, pol):
    del pol                                 # policy in scope but unused
    return u * n + v                        # FIRE: packing bypasses id_policy
