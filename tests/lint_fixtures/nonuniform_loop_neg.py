"""NEGATIVE nonuniform-loop fixtures: nothing here may fire."""
import jax
import jax.numpy as jnp


def static_schedule_spmd(view, shifts: tuple, widths: tuple):
    # python loop over a static round schedule: unrolls once, cached forever
    for k, w in zip(shifts, widths):
        view = view + k * w
    return view


def while_uniform_spmd(view, comm):
    def cond(state):
        c, n = state
        return n > 0                        # psum-derived: shard-agreed

    def body(state):
        c, n = state
        c = c - 1
        return c, comm.psum(jnp.sum(c))

    return jax.lax.while_loop(cond, body, (view, jnp.int32(1)))


def fori_pmax_bound_spmd(view, comm):
    n_steps = comm.pmax(jnp.sum(view > 0))  # reduced trip count

    def body(i, c):
        return comm.psum(c)

    return jax.lax.fori_loop(0, n_steps, body, view)


def fori_pure_body_spmd(view):
    n_local = jnp.sum(view > 0)             # divergent bound, but the body

    def body(i, c):                         # never communicates: allowed
        return c + 1

    return jax.lax.fori_loop(0, n_local, body, view)
