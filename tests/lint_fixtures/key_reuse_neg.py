"""NEGATIVE key-reuse fixtures: nothing here may fire."""
import jax


def split_then_use(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.uniform(k1, (4,))
    b = jax.random.normal(k2, (4,))
    return a + b


def fold_per_iteration(key, n):
    out = 0.0
    for i in range(n):
        ik = jax.random.fold_in(key, i)     # re-derived inside the loop
        out += jax.random.uniform(ik, ())
    return out


def rebound_key(key):
    a = jax.random.uniform(key, (4,))
    key = jax.random.fold_in(key, 1)        # fresh key, same name
    b = jax.random.normal(key, (4,))
    return a + b


def exclusive_branches(key, flag):
    if flag:
        return jax.random.uniform(key, ())
    else:
        return jax.random.normal(key, ())   # other arm of the same branch


def not_a_key(view, order):
    a = view[order]
    b = view[order]                          # plain arrays are not tracked
    return a + b
