"""POSITIVE divergent-collective fixtures: every marked line must fire."""
import jax
import jax.numpy as jnp


def shard_gated_exchange_spmd(view, comm):
    # predicate derives from the shard id -> shards disagree on the psum
    mine = comm.index() == 0
    ex = lambda v: comm.psum(v)
    return jax.lax.cond(mine, ex, lambda v: v, view)        # FIRE


def data_gated_exchange_spmd(view, comm):
    # predicate derives from per-shard data with no reduction
    pending = jnp.any(view > 0)
    ex = lambda v: comm.psum(v)
    return jax.lax.cond(pending, ex, lambda v: v, view)     # FIRE


def ppermute_derived_pred_spmd(view, comm, perm):
    # ppermute outputs are per-shard even from uniform inputs
    got = comm.ppermute(view, perm)
    ex = lambda v: comm.psum(v)
    return jax.lax.cond(jnp.any(got > 0), ex, lambda v: v, view)  # FIRE
