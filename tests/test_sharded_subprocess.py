"""Multi-device paths that need >1 XLA device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
stays at 1 device by design)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_coloring_equals_sim():
    print(run_sub("""
        import numpy as np, jax
        from repro.core import (rmat, partition_graph, compute_order,
                                ColorConfig, color_graph_sim,
                                color_graph_sharded, RecolorConfig,
                                recolor_sim, recolor_sharded,
                                colors_from_views, assert_valid, ordering)
        from repro.compat import make_mesh
        g = rmat.grid2d(32, 32, 9)
        pg = partition_graph(g, 8)
        order = compute_order(pg, ordering.SMALLEST_LAST)
        cfg = ColorConfig(max_colors=64, superstep=64)
        v_sim, s_sim = color_graph_sim(pg, order, cfg)
        mesh = make_mesh((8,), ("workers",))
        v_sh, s_sh = color_graph_sharded(pg, order, cfg, mesh)
        assert (np.asarray(v_sim) == np.asarray(v_sh)).all(), "views differ"
        rcfg = RecolorConfig(max_colors=64)
        key = jax.random.key(5)
        r_sim, _ = recolor_sim(pg, np.asarray(v_sim), "nd", rcfg, key=key)
        r_sh, _ = recolor_sharded(pg, np.asarray(v_sh), "nd", rcfg, mesh,
                                  key=key)
        assert (np.asarray(r_sim) == np.asarray(r_sh)).all(), "rc differs"
        assert_valid(g, colors_from_views(pg, np.asarray(r_sh)))
        print("sharded == sim OK")
    """))


@pytest.mark.slow
def test_color_many_sharded_equals_sim():
    """Batched multi-graph pipeline on a real workers mesh == sim executor
    (the graph batch axis rides inside each shard via vmap)."""
    print(run_sub("""
        import numpy as np
        from repro.core import (rmat, partition_graph, ColorConfig,
                                RecolorConfig, PipelineConfig, color_many,
                                color_many_sharded)
        from repro.compat import make_mesh
        graphs = [rmat.rmat_good(6, 8, seed=1), rmat.rmat_bad(6, 8, seed=2),
                  rmat.grid2d(16, 16, 9)]
        pgs = [partition_graph(g, 8) for g in graphs]
        cfg = PipelineConfig(color=ColorConfig(max_colors=64, superstep=64),
                             recolor=RecolorConfig(max_colors=64),
                             n_iters=3, patience=1)
        sim = color_many(pgs, cfg)
        mesh = make_mesh((8,), ("workers",))
        sh = color_many_sharded(pgs, cfg, mesh)
        for a, b in zip(sim, sh):
            assert np.array_equal(a["view"], b["view"]), "views differ"
            assert np.array_equal(a["colors"], b["colors"])
            assert a["history"] == b["history"] and a["color"] == b["color"]
            assert a["n_iters_run"] == b["n_iters_run"]
        print("color_many sharded == sim OK")
    """))


@pytest.mark.slow
def test_elastic_remesh_restore():
    """Save a sharded train state on a (2,) DP mesh, restore on (4,)."""
    print(run_sub("""
        import tempfile, numpy as np, jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        from repro.train import checkpoint as ckpt
        mesh2 = make_mesh((2,), ("data",))
        mesh4 = make_mesh((4,), ("data",))
        x = np.arange(64, dtype=np.float32).reshape(8, 8)
        tree = {"params": {"w": jax.device_put(
            x, NamedSharding(mesh2, P("data")))}}
        with tempfile.TemporaryDirectory() as td:
            ckpt.save(td, 5, tree)
            specs = {"params": {"w": P("data")}}
            step, back = ckpt.restore(td, mesh=mesh4, specs=specs)
            assert step == 5
            w = back["params"]["w"]
            assert len(w.sharding.device_set) == 4, w.sharding
            np.testing.assert_array_equal(np.asarray(w), x)
        print("elastic remesh OK")
    """))


@pytest.mark.slow
def test_compressed_dp_train_step_sharded():
    """int8 EF gradient all-reduce inside shard_map trains a toy model."""
    print(run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.train.compression import make_compressed_train_step

        mesh = make_mesh((8,), ("data",))

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {}

        def opt_update(params, grads, state):
            params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
            return params, state, {}

        step = make_compressed_train_step(loss_fn, opt_update, axis="data")
        w_true = np.random.default_rng(0).normal(0, 1, (8, 1)).astype(
            np.float32)
        params = {"w": jnp.zeros((8, 1))}
        err = {"w": jnp.zeros((8, 1))}
        state = {}
        smapped = jax.jit(shard_map(
            step, mesh=mesh,
            in_specs=(P(), P(), P(), P("data")),
            out_specs=(P(), P(), P(), P()), check=False))
        r = np.random.default_rng(1)
        for i in range(60):
            x = r.normal(0, 1, (64, 8)).astype(np.float32)
            y = x @ w_true
            params, state, err, info = smapped(params, state, err,
                                               {"x": x, "y": y})
        final = float(info["loss"])
        assert final < 1e-2, final
        print("compressed DP step OK, loss", final)
    """))


@pytest.mark.slow
def test_model_train_step_on_2x4_mesh():
    """Smoke arch train_step lowers + runs on a real (2,4) data×model mesh."""
    print(run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh, set_mesh
        from repro.configs import get_arch, smoke_of, plan_for_mesh
        from repro.data.pipeline import DataConfig, host_batch, device_batch
        from repro.launch.steps import make_train_step
        from repro.models import param_defs
        from repro.models.layers import ParamDef
        from repro.train.optimizer import OptConfig, init_opt_state
        from repro.train.trainer import init_params_sharded

        mesh = make_mesh((2, 4), ("data", "model"))
        plan = plan_for_mesh(mesh)
        arch = smoke_of(get_arch("moonshot_v1_16b_a3b"))
        pdefs = param_defs(arch)
        specs = jax.tree.map(lambda d: plan.spec(d.dims, d.shape), pdefs,
                             is_leaf=lambda t: isinstance(t, ParamDef))
        with set_mesh(mesh):
            params = init_params_sharded(pdefs, mesh, specs, 0)
            opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=2)
            opt = init_opt_state(params, opt_cfg)
            fn = jax.jit(make_train_step(arch, plan, opt_cfg))
            dc = DataConfig(vocab_size=arch.vocab_size, seq_len=32,
                            global_batch=4)
            losses = []
            for s in range(6):
                b = device_batch(host_batch(dc, s, arch), mesh, plan)
                params, opt, m = fn(params, opt, b)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("2x4 mesh train OK", [round(l, 3) for l in losses])
    """))
