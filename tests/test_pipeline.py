"""Fused device-resident pipeline (ISSUE 4) + quality-metric/RNG bugfixes.

The acceptance matrix of ``pipeline.color_then_recolor``: the fused program
(initial speculative coloring + K recoloring iterations in one
``lax.while_loop``) must be *bitwise identical* — views and every
per-iteration stat — to the host-looped ``color_graph_sim`` +
``recolor_iterations(fused=False)`` reference sequence, across P, exchange
schemes, and distance 1|2; the adaptive stop must fire on a plateaued
schedule.  The satellite regressions pin the corrected distinct-color
quality metric, the masked ``class_sizes`` scatter, and the per-call /
split RNG keys.
"""
import jax
import numpy as np
import pytest

from repro.core import (ColorConfig, Graph, PipelineConfig, RecolorConfig,
                        arc_sim, check_coloring, color_graph_sim,
                        colors_from_views, compute_order, ordering,
                        partition_graph, pipeline_sim, recolor_iterations,
                        recolor_sim, rmat)
from repro.core.comm import AxisComm, run_sim
from repro.core.recolor import class_sizes

MC = 512
CCFG = dict(max_colors=MC, superstep=64, seed=0)


def _graph():
    return rmat.rmat_good(8, 8, seed=3)


def _host_reference(pg, order, ccfg, rcfg, n_iters, **sched):
    view, cstats = color_graph_sim(pg, order, ccfg)
    view, hist = recolor_iterations(pg, np.asarray(view), n_iters, rcfg,
                                    fused=False, **sched)
    return np.asarray(view), cstats, hist


def _assert_pipeline_equals_host(pg, order, ccfg, rcfg, n_iters, **sched):
    v_host, _, hist_host = _host_reference(pg, order, ccfg, rcfg, n_iters,
                                           **sched)
    pcfg = PipelineConfig(color=ccfg, recolor=rcfg, n_iters=n_iters, **sched)
    v_fused, res = pipeline_sim(pg, order, pcfg)
    np.testing.assert_array_equal(np.asarray(v_fused), v_host)
    assert res["n_iters_run"] == n_iters
    assert res["history"] == hist_host        # every stat, every iteration
    return res


@pytest.mark.parametrize("P", [2, 4, 16])
def test_fused_equals_host_loop(P):
    """Fused == host loop bitwise (view + per-iteration stats), P sweep."""
    pg = partition_graph(_graph(), P)
    order = compute_order(pg, ordering.NATURAL)
    _assert_pipeline_equals_host(pg, order, ColorConfig(**CCFG),
                                 RecolorConfig(max_colors=MC), 5,
                                 base_perm="nd", rand_every=2, seed=0)


@pytest.mark.parametrize("scheme", ["sparse", "allgather"])
def test_fused_equals_host_loop_schemes(scheme):
    """Both boundary-exchange schemes, explicitly (beyond the CI matrix)."""
    pg = partition_graph(_graph(), 4)
    order = compute_order(pg, ordering.NATURAL)
    _assert_pipeline_equals_host(
        pg, order, ColorConfig(scheme=scheme, **CCFG),
        RecolorConfig(max_colors=MC, scheme=scheme), 4,
        base_perm="nd", rand_pow2=True, seed=1)


def test_fused_equals_host_loop_d2():
    """Distance-2 pipeline over the two-hop halo matches the host loop."""
    pg = partition_graph(_graph(), 4, halo=2)
    order = compute_order(pg, ordering.NATURAL)
    ccfg = ColorConfig(max_colors=MC, superstep=64, tile=16, max_rounds=256,
                       distance=2, seed=0)
    _assert_pipeline_equals_host(pg, order, ccfg,
                                 RecolorConfig(max_colors=MC, distance=2), 3,
                                 base_perm="nd", seed=0)


def test_recolor_iterations_fused_wrapper_bitwise():
    """The default (fused) recolor_iterations == its own host loop."""
    pg = partition_graph(_graph(), 4)
    order = compute_order(pg, ordering.NATURAL)
    view, _ = color_graph_sim(pg, order, ColorConfig(**CCFG))
    rcfg = RecolorConfig(max_colors=MC)
    kw = dict(base_perm="nd", rand_every=3, seed=5)
    v_host, h_host = recolor_iterations(pg, np.asarray(view), 6, rcfg,
                                        fused=False, **kw)
    v_fused, h_fused = recolor_iterations(pg, np.asarray(view), 6, rcfg, **kw)
    np.testing.assert_array_equal(np.asarray(v_fused), np.asarray(v_host))
    assert h_fused == h_host


def test_adaptive_stop_fires_on_plateau():
    """patience=k quits after k non-improving iterations (paper's knob)."""
    pg = partition_graph(_graph(), 4)
    order = compute_order(pg, ordering.NATURAL)
    pcfg = PipelineConfig(color=ColorConfig(**CCFG),
                          recolor=RecolorConfig(max_colors=MC),
                          n_iters=16, base_perm="nd", patience=2)
    view, res = pipeline_sim(pg, order, pcfg)
    assert res["n_iters_run"] < 16
    assert len(res["history"]) == res["n_iters_run"]
    cs = [h["n_colors_distinct"] for h in res["history"]]
    assert cs[-1] == cs[-2] == cs[-3]          # the plateau that tripped it
    # the stopped run is a bitwise prefix of the full run (patience only
    # truncates — the quality it trades away is exactly the paper's knob)
    pcfg_full = PipelineConfig(color=ColorConfig(**CCFG),
                               recolor=RecolorConfig(max_colors=MC),
                               n_iters=16, base_perm="nd")
    _, res_full = pipeline_sim(pg, order, pcfg_full)
    assert res["history"] == res_full["history"][: res["n_iters_run"]]


def test_pipeline_smoke_rmat_adaptive():
    """Tier-1 smoke: small RMAT, K=4, adaptive stop, valid end-to-end."""
    g = rmat.rmat_bad(8, 8, seed=1)
    pg = partition_graph(g, 4)
    order = compute_order(pg, ordering.INTERNAL_FIRST)
    pcfg = PipelineConfig(color=ColorConfig(max_colors=1024, superstep=64),
                          recolor=RecolorConfig(max_colors=1024),
                          n_iters=4, patience=2)
    view, res = pipeline_sim(pg, order, pcfg)
    colors = colors_from_views(pg, np.asarray(view))
    st = check_coloring(g, colors)
    assert st["valid"], st
    assert 1 <= res["n_iters_run"] <= 4
    last = res["history"][-1]
    assert st["n_colors"] == last["n_colors_distinct"]
    assert last["n_colors_distinct"] <= res["color"]["n_colors"]


def test_pipeline_partial_marked():
    """partial=True + marked flows through the fused pipeline unchanged."""
    g = rmat.grid2d(12, 12, 9)
    pg = partition_graph(g, 2, halo=2)
    marked_g = np.arange(g.n) % 2 == 0
    marked = np.zeros((pg.P, pg.n_local_max), bool)
    for p in range(pg.P):
        nl, lo = int(pg.n_local[p]), int(pg.offs[p])
        marked[p, :nl] = marked_g[lo: lo + nl]
    order = compute_order(pg, ordering.NATURAL)
    pcfg = PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=64, tile=16,
                          max_rounds=256, distance=2, partial=True),
        recolor=RecolorConfig(max_colors=MC, distance=2), n_iters=2)
    view, res = pipeline_sim(pg, order, pcfg, marked=marked)
    colors = colors_from_views(pg, np.asarray(view))
    assert (colors[~marked_g] == 0).all()
    chk = check_coloring(g, colors, distance=2, marked=marked_g)
    assert chk["valid"], chk


# ------------------------------------------------- satellite regressions --

def test_check_coloring_counts_distinct_colors():
    """A gappy coloring must report distinct colors, not the max id."""
    # path graph 0-1-2-3
    indptr = np.array([0, 1, 3, 5, 6], np.int64)
    indices = np.array([1, 0, 2, 1, 3, 2], np.int32)
    g = Graph(4, indptr, indices)
    colors = np.array([1, 9, 1, 9], np.int32)      # classes 2..8 are empty
    st = check_coloring(g, colors)
    assert st["valid"]
    assert st["n_colors"] == 2                     # was 9 before the fix
    assert st["max_color_id"] == 9
    assert g.num_colors(colors) == 2
    assert len(st["class_sizes"]) == 9             # still indexed by id
    assert st["class_sizes"][0] == 2 and st["class_sizes"][8] == 2


def test_check_coloring_gapfree_unchanged():
    """On gap-free colorings the corrected metric equals the old one."""
    g = _graph()
    pg = partition_graph(g, 4)
    order = compute_order(pg, ordering.NATURAL)
    view, stats = color_graph_sim(pg, order, ColorConfig(**CCFG))
    st = check_coloring(g, colors_from_views(pg, np.asarray(view)))
    assert st["n_colors"] == st["max_color_id"] == stats["n_colors"]
    assert stats["n_colors_distinct"] == st["n_colors"]


def test_color_stats_distinct_on_staggered_gaps():
    """Staggered FF leaves id gaps: device + host metrics must agree that
    the distinct count, not the max id, is the quality number."""
    g = _graph()
    pg = partition_graph(g, 4)
    order = compute_order(pg, ordering.NATURAL)
    view, stats = color_graph_sim(
        pg, order, ColorConfig(max_colors=MC, superstep=64,
                               selection="staggered", seed=0))
    st = check_coloring(g, colors_from_views(pg, np.asarray(view)))
    assert stats["n_colors_distinct"] == st["n_colors"]
    assert stats["n_colors"] == st["max_color_id"]
    assert stats["n_colors_distinct"] < stats["n_colors"]   # real gaps


def test_class_sizes_masks_out_of_range():
    """A poisoned view must not inflate the last class (clip-mode scatter)."""
    mc, n_local, n_local_max = 32, 6, 8
    view = np.array([1, 1, mc + 7, -3, 2, mc - 1, 0, 0, 0], np.int32)
    fn = lambda v: class_sizes(v, np.int32(n_local), n_local_max, mc,
                               AxisComm())
    sizes, n_oor = run_sim(fn, 1, (view[None],))
    sizes = np.asarray(sizes)[0]
    assert int(n_oor[0]) == 2                      # mc+7 and -3
    assert sizes[mc - 1] == 1                      # NOT silently 3
    assert sizes[1] == 2 and sizes[2] == 1
    assert sizes.sum() == 4                        # class 0 + poison excluded


def test_recolor_out_of_range_stat_surfaces():
    pg = partition_graph(_graph(), 2)
    order = compute_order(pg, ordering.NATURAL)
    view, _ = color_graph_sim(pg, order, ColorConfig(**CCFG))
    poisoned = np.asarray(view).copy()
    poisoned[0, 0] = MC + 5
    _, st = recolor_sim(pg, poisoned, "nd", RecolorConfig(max_colors=MC),
                        key=jax.random.key(0))
    assert st["n_out_of_range"] == 1
    _, st_ok = recolor_sim(pg, np.asarray(view), "nd",
                           RecolorConfig(max_colors=MC),
                           key=jax.random.key(0))
    assert st_ok["n_out_of_range"] == 0


def test_back_to_back_rand_iterations_differ():
    """Two manual RAND calls without keys must not replay one permutation."""
    pg = partition_graph(_graph(), 4)
    order = compute_order(pg, ordering.NATURAL)
    view, _ = color_graph_sim(pg, order, ColorConfig(**CCFG))
    cfg = RecolorConfig(max_colors=MC)
    v1, _ = recolor_sim(pg, np.asarray(view), "rand", cfg)
    v2, _ = recolor_sim(pg, np.asarray(view), "rand", cfg)
    assert (np.asarray(v1) != np.asarray(v2)).any()
    # explicit keys stay fully reproducible
    v3, _ = recolor_sim(pg, np.asarray(view), "rand", cfg,
                        key=jax.random.key(3))
    v4, _ = recolor_sim(pg, np.asarray(view), "rand", cfg,
                        key=jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(v3), np.asarray(v4))


def test_arc_back_to_back_differs_and_explicit_key_reproduces():
    """aRC default keys advance per call, and the rank/repair streams are
    split — Random-X makes the repair stream observable in the output."""
    pg = partition_graph(_graph(), 4)
    order = compute_order(pg, ordering.NATURAL)
    view, _ = color_graph_sim(pg, order, ColorConfig(**CCFG))
    rcfg = RecolorConfig(max_colors=MC)
    scfg = ColorConfig(max_colors=MC, superstep=64, selection="random_x",
                       random_x=10)
    v1, _ = arc_sim(pg, np.asarray(view), "rand", rcfg, scfg)
    v2, _ = arc_sim(pg, np.asarray(view), "rand", rcfg, scfg)
    assert (np.asarray(v1) != np.asarray(v2)).any()
    key = jax.random.key(9)
    v3, _ = arc_sim(pg, np.asarray(view), "rand", rcfg, scfg, key=key)
    v4, _ = arc_sim(pg, np.asarray(view), "rand", rcfg, scfg, key=key)
    np.testing.assert_array_equal(np.asarray(v3), np.asarray(v4))
