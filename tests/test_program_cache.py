"""The compiled-program cache: same-signature work compiles exactly once.

Trace-counter guards (DESIGN.md §2): ``program_cache_stats()["traces"]``
increments only when XLA actually retraces a cached driver program, so
these tests pin the tentpole property — N same-signature graphs through
``color_many`` and through the serving driver cost exactly one compile —
plus the fast 2-bucket serve smoke the CI tier-1 lane runs.
"""
import numpy as np
import pytest

from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                        bucket_signature, bucket_graphs, color_many,
                        compute_order, partition_graph, plan_signature,
                        program_cache_clear, program_cache_contains,
                        program_cache_stats, rmat)
from repro.core.pipeline import pipeline_sim
from repro.launch.serve_coloring import ColoringService, ServeConfig

P = 4


def _cfg(**kw):
    kw.setdefault("n_iters", 2)
    kw.setdefault("patience", 0)
    return PipelineConfig(color=ColorConfig(max_colors=64),
                          recolor=RecolorConfig(max_colors=64), **kw)


def _same_signature_pgs(seeds, scale=7):
    """Same topology, different tie-break priorities: identical dims and
    plan rungs (the plan depends on ghost structure only) but different
    colorings — genuinely distinct same-signature work items."""
    g = rmat.rmat_good(scale, 8, seed=3)
    return [partition_graph(g, P, seed=s) for s in seeds]


def test_color_many_same_signature_compiles_once():
    cfg = _cfg()
    pgs = _same_signature_pgs((0, 1, 2))
    sigs = {bucket_signature(b, cfg) for b in
            (bucket_graphs([pg])[0] for pg in pgs)}
    assert len(sigs) == 1                      # truly one signature
    program_cache_clear()
    out = color_many(pgs, cfg, pad_batch=True)
    st = program_cache_stats()
    assert (st["misses"], st["traces"]) == (1, 1)
    # a second wave of NEW same-signature graphs reuses the program
    out2 = color_many(_same_signature_pgs((3, 4, 5)), cfg, pad_batch=True)
    st = program_cache_stats()
    assert st["traces"] == 1                   # zero new compiles
    assert st["hits"] == 1
    assert len(out) == len(out2) == 3
    for r in out + out2:
        assert r["colors"].min() >= 1


def test_pipeline_sim_repeat_is_cache_hit():
    pg = _same_signature_pgs((0,))[0]
    cfg = _cfg()
    order = compute_order(pg, "internal_first")
    program_cache_clear()
    v1, _ = pipeline_sim(pg, order, cfg)
    v2, _ = pipeline_sim(pg, order, cfg)
    st = program_cache_stats()
    assert (st["misses"], st["hits"], st["traces"]) == (1, 1, 1)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_bucket_signature_probe_is_exact():
    """``bucket_signature`` predicts the program ``color_many`` compiles —
    the serving cost model's hit/miss probe never lies."""
    cfg = _cfg()
    pg_a, pg_b = _same_signature_pgs((0, 1))
    program_cache_clear()
    sig = bucket_signature(bucket_graphs([pg_a])[0], cfg)
    assert not program_cache_contains(sig)
    color_many([pg_a], cfg, pad_batch=True)
    sig_b = bucket_signature(bucket_graphs([pg_b])[0], cfg)
    assert sig_b == sig and program_cache_contains(sig_b)
    # dispatching B is then trace-free
    before = program_cache_stats()["traces"]
    color_many([pg_b], cfg, pad_batch=True)
    assert program_cache_stats()["traces"] == before


def test_serve_two_bucket_mix_cache_smoke():
    """CI tier-1 smoke: a 2-bucket traffic mix through the serve driver —
    N same-signature requests compile once, and the warm resubmission
    takes the solo path with a positive program-cache hit rate."""
    cfg = _cfg()
    graphs = [rmat.rmat_good(6, 8, seed=s) for s in (1, 2)] + \
             [rmat.rmat_good(7, 8, seed=s) for s in (1, 2)]
    program_cache_clear()
    # this test pins the *flush* scheduler's batch/solo routing; the
    # continuous engine's trace pins live in test_serve_continuous.py
    svc = ColoringService(P=P, cfg=cfg, validate=True,
                          serve=ServeConfig(mode="flush"))
    ids = [svc.submit(g) for g in graphs]
    cold = svc.flush()
    assert all(cold[i]["route"] == "batch" for i in ids)
    traces_cold = program_cache_stats()["traces"]
    # every signature compiled exactly once in the cold wave
    assert traces_cold == svc.stats()["signatures"]
    # prewarm compiles the one-lane programs (the cold wave compiled the
    # B=2 batch lanes); steady-state traffic then takes the solo hit path
    svc.prewarm(graphs)
    traces_warm = program_cache_stats()["traces"]
    ids2 = [svc.submit(g) for g in graphs]        # warm resubmission
    warm = svc.flush()
    assert all(warm[i]["route"] == "solo" for i in ids2)
    st = svc.stats()
    assert st["hits"] > 0
    hit_rate = st["hits"] / (st["hits"] + st["misses"])
    assert hit_rate > 0
    assert program_cache_stats()["traces"] == traces_warm  # no new compiles
    # request keys fold the request id, so the route never changes colors
    for i, i2 in zip(ids, ids2):
        assert cold[i]["check"]["valid"] and warm[i2]["check"]["valid"]
