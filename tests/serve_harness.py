"""Deterministic fake-clock harness for the continuous-batching scheduler.

Drives a ``ColoringService`` (with an injected ``FakeClock``) through a
scripted arrival sequence: time is virtual (one tick per poll by default),
arrivals are submitted exactly when the scripted clock reaches them, and
the event loop interleaves submits with scheduler polls — so mid-flight
lane admission, SLO sheds and deferrals replay *identically* on every
run.  Zero sleeps, zero wall-clock reads, zero flakes.

Usage (tests/test_serve_continuous.py, the CI ``serve-stress`` job):

    clock = FakeClock()
    svc = ColoringService(..., clock=clock, serve=ServeConfig(...))
    script = random_script(rng, graphs, n=20, mean_gap=1.5)
    res = run_script(svc, script)
    # res.results / res.shed / res.failed / res.futures

``benchmarks/bench_serve.py``'s open-loop sweep runs the same event loop
on a hybrid clock (its poll cost is the *measured* wall seconds of each
scheduler step, making latency percentiles load-dependent while arrivals
stay scripted).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.launch.serve_coloring import FakeClock, JobError, ShedError


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scripted request: submit ``graph`` when the clock reaches ``t``."""
    t: float
    graph: object
    marked: object = None


@dataclasses.dataclass
class ScriptResult:
    """What a scripted run produced.

    ``results`` — every completed result (failures included, keyed by
    request id); ``futures`` — every request's ``JobFuture``; ``shed`` /
    ``failed`` — ids rejected by admission control / failed in their
    lane; ``submit_t`` — scripted submit time per id; ``polls`` — total
    scheduler polls the script took to drain.
    """
    results: dict
    futures: dict
    shed: list
    failed: list
    submit_t: dict
    polls: int


def run_script(svc, arrivals, *, poll_cost: float = 1.0,
               max_polls: int = 20000) -> ScriptResult:
    """Drive ``svc`` through ``arrivals`` on its injected ``FakeClock``.

    Event loop: submit every arrival whose time has come, run one
    ``svc.poll()``, advance the clock by ``poll_cost`` (the scripted cost
    of a scheduler step — virtual seconds per poll, or measured wall
    seconds in the benchmark), repeat; when the service is idle, jump the
    clock straight to the next arrival.  With the default ``poll_cost=1``
    arrival times are effectively in poll ticks, so scripts express exact
    interleavings ("request 3 lands two chunks into request 1's run").
    """
    clock = svc._clock
    assert isinstance(clock, FakeClock), "inject a FakeClock into the service"
    pend = sorted(arrivals, key=lambda a: a.t)
    results: dict[int, dict] = {}
    futures: dict[int, object] = {}
    submit_t: dict[int, float] = {}
    i = polls = 0
    while i < len(pend) or svc.pending:
        if not svc.pending and i < len(pend) and pend[i].t > clock.now():
            clock.advance(pend[i].t - clock.now())
        while i < len(pend) and pend[i].t <= clock.now():
            a = pend[i]
            jid = svc.submit(a.graph, marked=a.marked)
            futures[jid] = svc.future(jid)
            submit_t[jid] = clock.now()
            i += 1
        results.update(svc.poll())
        clock.advance(poll_cost)
        polls += 1
        if polls > max_polls:
            raise RuntimeError(f"script did not drain in {max_polls} polls "
                               f"({svc.pending} pending)")
    shed = [jid for jid, f in futures.items()
            if isinstance(f.exception(), ShedError)]
    failed = [jid for jid, f in futures.items()
              if f.exception() is not None
              and not isinstance(f.exception(), ShedError)]
    for jid, f in futures.items():
        assert f.done(), f"request {jid} unresolved after drain"
        if f.exception() is None:
            assert jid in results, jid
        elif isinstance(f.exception(), JobError):
            pass
    return ScriptResult(results=results, futures=futures, shed=shed,
                        failed=failed, submit_t=submit_t, polls=polls)


def random_script(rng: np.random.Generator, graphs, *, n: int,
                  mean_gap: float) -> list[Arrival]:
    """A seeded random arrival script: exponential gaps (Poisson process,
    mean ``mean_gap`` virtual seconds) over a uniform mix of ``graphs``."""
    ts = np.cumsum(rng.exponential(mean_gap, size=n))
    idx = rng.integers(0, len(graphs), size=n)
    return [Arrival(float(t), graphs[int(j)]) for t, j in zip(ts, idx)]
