"""Sparse neighbour-to-neighbour exchange vs the all-gather scheme.

The two schemes must be *bitwise interchangeable* (DESIGN.md §2): same
colorings from both drivers for any graph/partition, with the sparse scheme
shipping no more bytes than the broadcast — and exactly zero bytes when the
partition has zero cross edges.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ColorConfig, RecolorConfig, assert_valid,
                        color_graph_sim, colors_from_views, compute_order,
                        ordering, partition_graph, recolor_sim, rmat,
                        stats_to_host)
from repro.core.graph import Graph


def _run_both(pg, order, mk_cfg):
    views, stats = {}, {}
    for scheme in ("allgather", "sparse"):
        views[scheme], stats[scheme] = mk_cfg(scheme)
    return views, stats


def _assert_views_equal(pg, va, vs):
    """Bitwise equality over every *meaningful* slot.

    The two schemes treat ghost-slot padding differently (the all-gather
    refresh writes ``table[0, 0]`` into padded ghosts, the sparse rounds
    never touch them), so only local slots and each shard's real ghosts are
    compared.
    """
    va, vs = np.asarray(va), np.asarray(vs)
    np.testing.assert_array_equal(va[:, : pg.n_local_max],
                                  vs[:, : pg.n_local_max])
    for p in range(pg.P):
        ng = int(pg.n_ghost[p])
        np.testing.assert_array_equal(
            va[p, pg.n_local_max : pg.n_local_max + ng],
            vs[p, pg.n_local_max : pg.n_local_max + ng])


# --------------------------------------------------- scheme equivalence ----

@pytest.mark.parametrize("P", [2, 4, 8])
@pytest.mark.parametrize("seed", [3, 11])
def test_sparse_equals_allgather_speculative(P, seed):
    """Seeded RMAT sweep: identical colorings, no more wire bytes."""
    g = rmat.rmat_good(9, 8, seed=seed)
    pg = partition_graph(g, P)
    order = compute_order(pg, ordering.NATURAL)
    views, stats = _run_both(pg, order, lambda s: color_graph_sim(
        pg, order, ColorConfig(max_colors=512, superstep=64, seed=0,
                               scheme=s)))
    _assert_views_equal(pg, views["allgather"], views["sparse"])
    assert_valid(g, colors_from_views(pg, np.asarray(views["sparse"])))
    assert stats["sparse"]["n_exchanges"] == stats["allgather"]["n_exchanges"]
    if P > 1:
        assert 0 < stats["sparse"]["wire_bytes"] <= \
            stats["allgather"]["wire_bytes"]


@pytest.mark.parametrize("P", [2, 4, 8])
def test_sparse_equals_allgather_recolor(P):
    """Both recoloring drivers agree across schemes (and stay valid)."""
    import jax
    g = rmat.rmat_good(9, 8, seed=5)
    pg = partition_graph(g, P)
    order = compute_order(pg, ordering.NATURAL)
    seed_view, _ = color_graph_sim(
        pg, order, ColorConfig(max_colors=512, superstep=64, seed=0))
    key = jax.random.key(7)
    views, stats = _run_both(pg, order, lambda s: recolor_sim(
        pg, seed_view, "nd", RecolorConfig(max_colors=512, scheme=s),
        key=key))
    _assert_views_equal(pg, views["allgather"], views["sparse"])
    assert_valid(g, colors_from_views(pg, np.asarray(views["sparse"])))
    assert stats["sparse"]["n_exchanges"] == stats["allgather"]["n_exchanges"]
    if P > 1:
        assert 0 < stats["sparse"]["wire_bytes"] <= \
            stats["allgather"]["wire_bytes"]


def test_sparse_piggyback_equals_per_step():
    """Per-link round masks still deliver every color just in time."""
    import jax
    g = rmat.rmat_good(9, 8, seed=5)
    pg = partition_graph(g, 4)
    order = compute_order(pg, ordering.NATURAL)
    seed_view, _ = color_graph_sim(
        pg, order, ColorConfig(max_colors=512, superstep=64, seed=0))
    key = jax.random.key(3)
    v_pig, st_pig = recolor_sim(pg, seed_view, "nd", RecolorConfig(
        max_colors=512, piggyback=True, scheme="sparse"), key=key)
    v_all, st_all = recolor_sim(pg, seed_view, "nd", RecolorConfig(
        max_colors=512, piggyback=False, scheme="sparse"), key=key)
    _assert_views_equal(pg, v_pig, v_all)
    assert st_pig["wire_bytes"] < st_all["wire_bytes"]


# ------------------------------------------------ zero-cross-edge graphs ----

def _disjoint_cliques(k: int, size: int) -> Graph:
    """k cliques of `size` vertices, no edges between them."""
    n = k * size
    rows, cols = [], []
    for c in range(k):
        base = c * size
        for v in range(size):
            for u in range(size):
                if u != v:
                    rows.append(base + v)
                    cols.append(base + u)
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, np.asarray(rows) + 1, 1)
    return Graph(n, np.cumsum(indptr), np.asarray(cols, np.int32))


def test_zero_cross_edges_zero_sparse_bytes():
    """Block partition along component boundaries: no rounds, no bytes."""
    g = _disjoint_cliques(4, 8)
    pg = partition_graph(g, 4)                 # blocks == components
    assert (pg.n_ghost == 0).all() and (pg.n_boundary == 0).all()
    plan = pg.comm_plan
    assert plan.shifts == () and plan.bytes_per_exchange() == 0
    order = compute_order(pg, ordering.NATURAL)
    view, st = color_graph_sim(pg, order, ColorConfig(
        max_colors=64, superstep=8, scheme="sparse"))
    assert_valid(g, colors_from_views(pg, np.asarray(view)))
    assert st["wire_bytes"] == 0
    # ... and no exchange events at all: nothing was ever pending
    assert st["n_exchanges"] == 0
    # the broadcast scheme ships (P-1)*max_b bytes per event regardless
    _, st_ag = color_graph_sim(pg, order, ColorConfig(
        max_colors=64, superstep=8, scheme="allgather"))
    assert st_ag["wire_bytes"] == 0  # elided: no boundary vertex ever colored


def test_zero_cross_edges_zero_recolor_bytes():
    g = _disjoint_cliques(4, 8)
    pg = partition_graph(g, 4)
    order = compute_order(pg, ordering.NATURAL)
    view, _ = color_graph_sim(pg, order, ColorConfig(max_colors=64,
                                                     superstep=8))
    v2, st = recolor_sim(pg, view, "nd",
                         RecolorConfig(max_colors=64, scheme="sparse"))
    assert_valid(g, colors_from_views(pg, np.asarray(v2)))
    assert st["wire_bytes"] == 0


# ------------------------------------------------------- plan structure ----

def test_comm_plan_structure():
    g = rmat.rmat_good(9, 8, seed=3)
    pg = partition_graph(g, 4)
    plan = pg.comm_plan
    P = pg.P
    # n_send[p, q] counts exactly q's ghosts owned by p
    for q in range(P):
        ng = int(pg.n_ghost[q])
        owners = pg.ghost_owner[q, :ng]
        for p in range(P):
            assert plan.n_send[p, q] == int((owners == p).sum())
    # exact widths are the per-shift maxima; compiled widths are their
    # pow2 rungs (shape-static quantization); every send row is
    # sentinel-padded out to the rung
    from repro.core.graph import _ceil_pow2
    for r, k in enumerate(plan.shifts):
        counts = [plan.n_send[p, (p + k) % P] for p in range(P)]
        assert plan.exact_widths[r] == max(counts)
        assert plan.widths[r] == _ceil_pow2(max(counts))
        for p in range(P):
            row = plan.send_slot[p, r]
            c = plan.n_send[p, (p + k) % P]
            assert (row[:c] < pg.n_local_max).all()          # local slots
            assert (row[c:] == pg.sentinel).all()
            # the slots p sends to q are exactly q's ghosts owned by p,
            # ascending by global id
            q = (p + k) % P
            ngq = int(pg.n_ghost[q])
            vids = pg.gvid[q, pg.n_local_max : pg.n_local_max + ngq]
            mine = vids[pg.ghost_owner[q, :ngq] == p] - pg.offs[p]
            np.testing.assert_array_equal(row[:c], mine)
    # receive side: ghost g refreshes from position ghost_pos of round
    # shift_to_round[ghost_shift]
    for q in range(P):
        ng = int(pg.n_ghost[q])
        for gi in range(ng):
            p = int(pg.ghost_owner[q, gi])
            k = int(plan.ghost_shift[q, gi])
            assert k == (q - p) % P
            r = int(plan.shift_to_round[q, k])
            assert plan.shifts[r] == k
            slot = plan.send_slot[p, r, int(plan.ghost_pos[q, gi])]
            assert pg.gvid[p, slot] == pg.gvid[q, pg.n_local_max + gi]


def test_stats_to_host_handles_0d_and_stacked():
    out = stats_to_host(dict(a=jnp.int32(3), b=jnp.full((4,), 7, jnp.int32)))
    assert out == dict(a=3, b=7)
    assert all(isinstance(v, int) for v in out.values())


def test_wire16_halves_sparse_bytes():
    g = rmat.rmat_good(9, 8, seed=3)
    pg = partition_graph(g, 4)
    order = compute_order(pg, ordering.NATURAL)
    mk = lambda w: stats_to_host(color_graph_sim(pg, order, ColorConfig(
        max_colors=512, superstep=64, scheme="sparse", wire16=w))[1])
    assert mk(True)["wire_bytes"] * 2 == mk(False)["wire_bytes"]
