"""Continuous-batching scheduler tests on the deterministic fake clock.

The core invariant (the PR 5 lane-equality pin extended to the serving
engine): under *arbitrary* admission interleavings — scripted or random
arrivals, any lanes/chunk/SLO settings — every accepted job's engine
result is bitwise-equal to a solo ``pipeline_sim`` run of the same
engine-padded member with the same request-id-folded keys.  Plus: future
semantics, SLO shed / queue-bound determinism, per-lane fault isolation,
stats-counter consistency, and retrace stability across scripts.

``SERVE_STRESS_SCRIPTS`` scales the seeded stress sweep (default 25
locally; the CI ``serve-stress`` job runs 200+).  The hypothesis variant
of the same property runs when hypothesis is installed (CI test extras).
"""
import os

import jax
import numpy as np
import pytest

from repro.core import (ColorConfig, Graph, PipelineConfig, RecolorConfig,
                        compute_order, pipeline_sim, program_cache_stats,
                        rmat)
from repro.launch.serve_coloring import (ColoringService, FakeClock,
                                         JobError, ServeConfig, ShedError)
from serve_harness import Arrival, random_script, run_script

P = 2


def _cfg(scheme: str = "sparse", n_iters: int = 3,
         patience: int = 1) -> PipelineConfig:
    return PipelineConfig(
        color=ColorConfig(max_colors=64, superstep=32, selection="random_x",
                          random_x=10, scheme=scheme),
        recolor=RecolorConfig(max_colors=64, scheme=scheme),
        n_iters=n_iters, patience=patience)


def _pool():
    """A small mixed pool: ≥2 shape buckets at P=2."""
    return [rmat.rmat_good(4, 8, seed=1), rmat.rmat_bad(4, 8, seed=2),
            rmat.rmat_er(5, 8, seed=3), rmat.grid2d(8, 8, 5)]


def _clique(n: int) -> Graph:
    ind, indptr = [], [0]
    for u in range(n):
        ind += [v for v in range(n) if v != u]
        indptr.append(len(ind))
    return Graph(n=n, indptr=np.array(indptr), indices=np.array(ind))


def _svc(cfg=None, *, validate=True, **serve_kw) -> ColoringService:
    return ColoringService(P=P, cfg=cfg or _cfg(), validate=validate,
                           clock=FakeClock(), serve=ServeConfig(**serve_kw))


def _assert_bitwise(svc: ColoringService, results: dict) -> int:
    """Every engine-route result == solo pipeline_sim of its padded member
    (same folded keys, same resolved config) — views, colors, history and
    iteration counts all bitwise."""
    n = 0
    for jid, r in results.items():
        if r["route"] != "engine" or "error" in r:
            continue
        m, rcfg = r["member"], r["cfg"]
        ck = jax.random.fold_in(jax.random.key(rcfg.color.seed), jid)
        rk = jax.random.fold_in(jax.random.key(rcfg.seed), jid)
        view, solo = pipeline_sim(m, compute_order(m, svc.order_kind), rcfg,
                                  color_key=ck, recolor_key=rk)
        colors = m.gather_global_colors(
            np.asarray(view)[:, :m.n_local_max])
        np.testing.assert_array_equal(colors, r["colors"], err_msg=str(jid))
        assert solo["history"] == r["history"], jid
        assert solo["n_iters_run"] == r["n_iters_run"], jid
        n += 1
    return n


def test_continuous_round_trip():
    """Submit a mixed queue, flush: every job valid, engine-routed, and
    bitwise its solo run; pending/stats transitions are consistent."""
    svc = _svc(lanes=2, chunk_iters=1, solo_warm=False)
    graphs = _pool()
    ids = [svc.submit(g) for g in graphs + graphs[::-1]]
    assert svc.pending == len(ids)
    res = svc.flush()
    assert sorted(res) == ids
    assert svc.pending == 0
    for i in ids:
        assert res[i]["check"]["valid"], (i, res[i]["check"])
        assert res[i]["route"] == "engine"
        assert res[i]["latency_s"] >= 0
    assert _assert_bitwise(svc, res) == len(ids)
    st = svc.stats()
    assert st["lane"] == len(ids) and st["n_shed"] == 0
    assert st["queued"] == st["running"] == 0


def test_futures_resolve_without_flush():
    """submit_async futures resolve by driving poll() — no flush call."""
    svc = _svc(lanes=2)
    futs = [svc.submit_async(g) for g in _pool()]
    outs = [f.result() for f in futs]
    for f, out in zip(futs, outs):
        assert f.done() and f.exception() is None
        assert out["check"]["valid"]
    assert svc.pending == 0


def test_mid_flight_admission_bitwise():
    """Arrivals staggered to land while earlier lanes are mid-run: the
    admission swap must not perturb any neighbor lane (bitwise pin)."""
    graphs = _pool()
    svc = _svc(lanes=2, chunk_iters=1, solo_warm=False)
    script = [Arrival(float(t), graphs[t % len(graphs)]) for t in range(8)]
    out = run_script(svc, script)
    assert not out.shed and not out.failed
    # with 2 lanes, 1-iteration chunks and one arrival per poll tick, later
    # jobs were necessarily admitted while earlier lanes were still running
    assert out.polls > 4
    assert _assert_bitwise(svc, out.results) == len(script)


def test_engine_reuse_no_retrace():
    """A second service running the same script reuses every compiled
    engine program — zero new XLA traces (the continuous analog of the
    PR 6 program-cache pin)."""
    graphs = _pool()
    script = [Arrival(float(t), graphs[t % len(graphs)]) for t in range(6)]
    run_script(_svc(lanes=2, solo_warm=False, validate=False), script)
    before = program_cache_stats()["traces"]
    out = run_script(_svc(lanes=2, solo_warm=False, validate=False), script)
    assert len(out.results) == len(script)
    assert program_cache_stats()["traces"] == before


def test_slo_shed_deterministic():
    """One lane, three simultaneous arrivals, SLO of 1.5 virtual seconds:
    the lane takes 3 ticks, so exactly the two waiting jobs age past the
    SLO and shed — the same two on every run."""
    g = _pool()[0]
    svc = _svc(_cfg(n_iters=3, patience=0), lanes=1, chunk_iters=1,
               slo_s=1.5, solo_warm=False)
    out = run_script(svc, [Arrival(0.0, g)] * 3)
    ids = sorted(out.futures)
    assert out.shed == ids[1:]
    assert sorted(out.results) == ids[:1]
    for jid in out.shed:
        with pytest.raises(ShedError):
            out.futures[jid].result()
    st = svc.stats()
    assert st["n_shed"] == 2
    assert st["n_deferred"] == 2      # both waited at least one poll first
    assert _assert_bitwise(svc, out.results) == 1


def test_queue_bound_sheds_at_submit():
    """Submits past max_queue shed immediately with a ShedError future."""
    svc = _svc(lanes=1, max_queue=2, solo_warm=False)
    g = _pool()[0]
    ids = [svc.submit(g) for _ in range(4)]
    st = svc.stats()
    assert st["n_shed"] == 2 and st["queued"] == 2
    assert svc.pending == 2
    for jid in ids[2:]:
        assert isinstance(svc.future(jid).exception(), ShedError)
    res = svc.flush()
    assert sorted(res) == ids[:2]


def test_fault_isolation_saturated_lane():
    """A lane whose graph saturates ``find_first_zero`` (clique wider than
    max_colors leaks uncolored sentinels) fails only its own job; the
    engine drains every neighboring lane to a valid result."""
    svc = _svc(_cfg(n_iters=2, patience=0), validate=False, lanes=2,
               solo_warm=False)
    assert svc.cfg.color.max_colors == 64
    graphs = [_clique(80)] + _pool()[:3]   # K80 needs 80 > 64: saturates
    futs = [svc.submit_async(g) for g in graphs]
    res = svc.flush()
    bad_id = futs[0].id
    with pytest.raises(JobError):
        futs[0].result()
    assert "error" in res[bad_id]
    assert res[bad_id]["check"]["valid"] is False
    for f in futs[1:]:
        out = f.result()                   # engine kept draining
        assert "error" not in out
    st = svc.stats()
    assert st["n_failed"] == 1 and st["lane"] == len(graphs) - 1
    assert _assert_bitwise(svc, res) == len(graphs) - 1


def test_n_iters_zero_lane():
    """K=0 (color-only) engine lanes complete on their first step with an
    empty history — and still match the solo run."""
    svc = _svc(_cfg(n_iters=0), lanes=2, solo_warm=False)
    for g in _pool()[:2]:
        svc.submit(g)
    res = svc.flush()
    for r in res.values():
        assert r["history"] == [] and r["n_iters_run"] == 0
        assert r["check"]["valid"]
    assert _assert_bitwise(svc, res) == 2


def _run_random_script(k: int, graphs, *, verify: bool = True):
    """One seeded random scenario: arrivals, lanes, chunking, SLO all
    drawn from a per-script rng; returns (svc, ScriptResult)."""
    rng = np.random.default_rng(10_000 + k)
    svc = _svc(lanes=int(rng.choice([1, 2, 4])),
               chunk_iters=int(rng.choice([1, 2])),
               slo_s=(None if rng.random() < 0.5
                      else float(rng.uniform(4.0, 12.0))),
               solo_warm=bool(rng.random() < 0.3),
               validate=False)
    script = random_script(rng, graphs, n=int(rng.integers(5, 12)),
                           mean_gap=float(rng.uniform(0.3, 3.0)))
    out = run_script(svc, script)
    # conservation: every submitted job resolved exactly one way
    assert len(out.results) + len(out.shed) == len(script)
    assert not out.failed
    assert svc.pending == 0
    st = svc.stats()
    assert st["n_shed"] == len(out.shed)
    assert st["lane"] + st["solo"] == len(out.results)
    if verify:
        _assert_bitwise(svc, out.results)
    return svc, out


def test_stress_random_scripts():
    """The acceptance property: across N generated arrival scripts (N =
    ``$SERVE_STRESS_SCRIPTS``, 200+ in CI), every accepted job is bitwise
    its solo run and the scheduler's accounting balances."""
    n_scripts = int(os.environ.get("SERVE_STRESS_SCRIPTS", "25"))
    graphs = _pool()
    n_bitwise = 0
    for k in range(n_scripts):
        _, out = _run_random_script(k, graphs)
        n_bitwise += len(out.results)
    assert n_bitwise > 0


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st_h
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_property_hypothesis_scripts():
    """Same property, hypothesis-driven: random arrival scripts / graph
    mixes / SLO settings never perturb a lane (shrinks on failure)."""

    graphs = _pool()

    @settings(max_examples=15, deadline=None)
    @given(seed=st_h.integers(min_value=0, max_value=2**20),
           lanes=st_h.sampled_from([1, 2, 4]),
           chunk=st_h.sampled_from([1, 2]),
           slo=st_h.sampled_from([None, 5.0, 10.0]))
    def prop(seed, lanes, chunk, slo):
        rng = np.random.default_rng(seed)
        svc = _svc(lanes=lanes, chunk_iters=chunk, slo_s=slo,
                   solo_warm=False, validate=False)
        out = run_script(svc, random_script(rng, graphs,
                                            n=int(rng.integers(4, 10)),
                                            mean_gap=1.0))
        assert len(out.results) + len(out.shed) == len(out.futures)
        _assert_bitwise(svc, out.results)

    prop()
