"""ELL layout + backend-switch hot paths: equivalence and regression pins.

No hypothesis dependency — this module must collect and run on a bare
environment (jax + numpy + pytest only).

Goldens below were captured from the seed implementation (scalar greedy
chunks; dense-occupancy recolor steps) before the ELL/bitset rework, so they
pin "parallel_chunk=False == seed behavior" and "chunked recolor == seed
recolor" bitwise for fixed seeds.
"""
import hashlib

import jax
import numpy as np
import pytest

from repro.core import (ColorConfig, RecolorConfig, assert_valid,
                        color_graph_sim, colors_from_views, compute_order,
                        ordering, partition_graph, recolor_sim, rmat,
                        select_colors, selection)
from repro.kernels import ops, ref


def _hash(colors: np.ndarray) -> str:
    return hashlib.sha256(colors.astype(np.int32).tobytes()).hexdigest()[:16]


@pytest.fixture(scope="module")
def graph():
    return rmat.rmat_good(10, 8, seed=3)


@pytest.fixture(scope="module")
def pgraph(graph):
    return partition_graph(graph, 4)


# ------------------------------------------------------------- ELL layout --

def test_ell_matches_csr(pgraph):
    pg = pgraph
    assert pg.nbr.shape == (pg.P, pg.n_local_max, pg.maxd)
    for p in range(pg.P):
        nl = int(pg.n_local[p])
        for v in range(0, nl, 37):          # sampled rows
            s, e = pg.indptr[p][v], pg.indptr[p][v + 1]
            csr_row = sorted(pg.indices[p][s:e].tolist())
            ell_row = pg.nbr[p, v]
            assert sorted(ell_row[: e - s].tolist()) == csr_row
            assert (ell_row[e - s:] == pg.sentinel).all()
        # padded vertex rows are all-sentinel
        assert (pg.nbr[p, nl:] == pg.sentinel).all()


# ------------------------------------------- select_colors backend switch --

@pytest.mark.parametrize("selname,kw", [
    (ops.FIRST_FIT, {}),
    (ops.RANDOM_X, dict(x=7)),
    (ops.STAGGERED, {}),
])
def test_select_backends_agree(selname, kw):
    rng = np.random.default_rng(5)
    v, d, mc = 300, 21, 128
    nbr = rng.integers(-2, mc + 8, (v, d)).astype(np.int32)
    active = rng.random(v) < 0.85
    rand = rng.integers(0, 2**32, v, dtype=np.uint32)
    off = rng.integers(0, mc, v).astype(np.int32)
    if selname == ops.STAGGERED:
        kw = dict(kw, offset=off)
    got_x = select_colors(nbr, active, rand, max_colors=mc,
                          selection=selname, backend="xla", **kw)
    got_p = select_colors(nbr, active, rand, max_colors=mc,
                          selection=selname, backend="pallas", **kw)
    np.testing.assert_array_equal(np.asarray(got_x), np.asarray(got_p))


def test_select_matches_ref_oracles():
    import jax.numpy as jnp
    rng = np.random.default_rng(9)
    v, d, mc = 257, 13, 64
    nbr = rng.integers(-2, mc + 4, (v, d)).astype(np.int32)
    active = rng.random(v) < 0.9
    rand = rng.integers(0, 2**32, v, dtype=np.uint32)
    ff = select_colors(nbr, active, max_colors=mc, backend="xla")
    np.testing.assert_array_equal(
        np.asarray(ff),
        np.asarray(ref.first_fit(jnp.asarray(nbr), jnp.asarray(active), mc)))
    rx = select_colors(nbr, active, rand, max_colors=mc,
                       selection=ops.RANDOM_X, x=5, backend="xla")
    np.testing.assert_array_equal(
        np.asarray(rx),
        np.asarray(ref.random_x(jnp.asarray(nbr), jnp.asarray(active),
                                jnp.asarray(rand), 5, mc)))


def test_detect_conflicts_backends_agree():
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    v, d, mc = 300, 17, 64
    myc = rng.integers(0, mc, v).astype(np.int32)
    myp = rng.integers(0, 10_000, v).astype(np.int32)
    nbrc = rng.integers(-2, mc + 8, (v, d)).astype(np.int32)
    nbrp = rng.integers(0, 10_000, (v, d)).astype(np.int32)
    active = rng.random(v) < 0.85
    got_x = ops.detect_conflicts(myc, myp, jnp.asarray(nbrc),
                                 jnp.asarray(nbrp), active, backend="xla")
    got_p = ops.detect_conflicts(myc, myp, jnp.asarray(nbrc),
                                 jnp.asarray(nbrp), active, backend="pallas")
    want = ref.conflict(jnp.asarray(myc), jnp.asarray(myp), jnp.asarray(nbrc),
                        jnp.asarray(nbrp), jnp.asarray(active))
    np.testing.assert_array_equal(np.asarray(got_x), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_staggered_saturation_boundary(backend):
    """In-kernel offset path: color 32W-1 is a true saturation sentinel.

    Rows whose neighbours occupy every legal color must come back as the
    sentinel ``mc-1``; a row with exactly the last legal color (``mc-2``)
    free at/above the offset must take it instead of wrapping below.
    """
    mc = 64
    full = np.arange(1, mc - 1, dtype=np.int32)      # colors 1..62
    rows = np.stack([
        full,                                        # only reserved 63 left
        np.where(full == 5, 0, full),                # free = {5, 63}
        np.where(full == mc - 2, 0, full),           # free = {62, 63}
    ])
    got = select_colors(rows, np.ones(3, bool), max_colors=mc,
                        selection=ops.STAGGERED, offset=np.full(3, 40,
                                                                np.int32),
                        backend=backend)
    np.testing.assert_array_equal(np.asarray(got), [mc - 1, 5, mc - 2])
    ff = select_colors(rows, np.ones(3, bool), max_colors=mc, backend=backend)
    np.testing.assert_array_equal(np.asarray(ff), [mc - 1, 5, mc - 2])


def test_select_rejects_unknowns():
    nbr = np.zeros((4, 2), np.int32)
    with pytest.raises(ValueError):
        select_colors(nbr, np.ones(4, bool), max_colors=64,
                      selection="least_used")
    with pytest.raises(ValueError):
        select_colors(nbr, np.ones(4, bool), max_colors=64, backend="cuda")


# --------------------------------- speculative: parallel_chunk vs the seed --

SEED_GOLD = {
    selection.FIRST_FIT: (13, "800e80e743f3eb16"),
    selection.RANDOM_X: (31, "ff78aa0d5bd44635"),
    selection.STAGGERED: (196, "159b9ed81e9a13e6"),
}


@pytest.mark.parametrize("selname", list(SEED_GOLD))
def test_sequential_mode_is_seed_behavior(graph, pgraph, selname):
    """parallel_chunk=False reproduces the pre-rework coloring bitwise."""
    order = compute_order(pgraph, ordering.NATURAL)
    cfg = ColorConfig(max_colors=512, superstep=64, selection=selname,
                      random_x=10, seed=0, parallel_chunk=False)
    view, st = color_graph_sim(pgraph, order, cfg)
    colors = colors_from_views(pgraph, np.asarray(view))
    want_nc, want_hash = SEED_GOLD[selname]
    assert st["n_colors"] == want_nc
    assert _hash(colors) == want_hash


@pytest.mark.parametrize("selname", [selection.FIRST_FIT, selection.STAGGERED,
                                     selection.RANDOM_X])
def test_parallel_mode_valid_and_backends_agree(graph, pgraph, selname):
    order = compute_order(pgraph, ordering.NATURAL)
    mk = lambda b: ColorConfig(max_colors=512, superstep=64,
                               selection=selname, seed=0, backend=b)
    view_x, st_x = color_graph_sim(pgraph, order, mk("xla"))
    assert_valid(graph, colors_from_views(pgraph, np.asarray(view_x)),
                 what=f"parallel-{selname}")
    view_p, st_p = color_graph_sim(pgraph, order, mk("pallas"))
    np.testing.assert_array_equal(np.asarray(view_x), np.asarray(view_p))
    assert st_x["n_colors"] == st_p["n_colors"]


# ------------------------------------------- recolor: chunked ELL vs seed --

RC_GOLD = {
    "nd": (11, 13, "f578174af31ddb61"),
    "rv": (11, 13, "b9f1ceb928314ffc"),
    "rand": (12, 13, "94da33bfa39399a0"),
}


@pytest.fixture(scope="module")
def seed_view(pgraph):
    order = compute_order(pgraph, ordering.NATURAL)
    view, _ = color_graph_sim(
        pgraph, order, ColorConfig(max_colors=512, superstep=64, seed=0,
                                   parallel_chunk=False))
    return view


@pytest.mark.parametrize("perm", list(RC_GOLD))
def test_recolor_chunked_is_seed_behavior(pgraph, seed_view, perm):
    """Chunked ELL/bitset recolor == the seed dense-occupancy recolor."""
    v2, st = recolor_sim(pgraph, seed_view, perm, RecolorConfig(max_colors=512),
                         key=jax.random.key(11))
    colors = colors_from_views(pgraph, np.asarray(v2))
    want_nc, want_ex, want_hash = RC_GOLD[perm]
    assert st["n_colors"] == want_nc
    assert st["n_exchanges"] == want_ex
    assert _hash(colors) == want_hash


def test_recolor_backends_agree(pgraph, seed_view):
    key = jax.random.key(11)
    v_x, _ = recolor_sim(pgraph, seed_view, "nd",
                         RecolorConfig(max_colors=512, backend="xla"), key=key)
    v_p, _ = recolor_sim(pgraph, seed_view, "nd",
                         RecolorConfig(max_colors=512, backend="pallas"),
                         key=key)
    np.testing.assert_array_equal(np.asarray(v_x), np.asarray(v_p))


def test_recolor_odd_chunk_size(graph, pgraph, seed_view):
    """Chunk size must not change the result (class = independent set)."""
    key = jax.random.key(11)
    v_a, _ = recolor_sim(pgraph, seed_view, "nd",
                         RecolorConfig(max_colors=512, chunk=256), key=key)
    v_b, _ = recolor_sim(pgraph, seed_view, "nd",
                         RecolorConfig(max_colors=512, chunk=19), key=key)
    np.testing.assert_array_equal(np.asarray(v_a), np.asarray(v_b))


# ------------------------------------------------------------ wire16 guard --

def test_wire16_guard():
    """int16 wire payloads cap max_colors at 32767 (silent aliasing past it)."""
    RecolorConfig(max_colors=4096, wire16=True)          # fine
    ColorConfig(max_colors=4096, wire16=True)            # fine
    with pytest.raises(AssertionError):
        RecolorConfig(max_colors=32768, wire16=True)
    with pytest.raises(AssertionError):
        ColorConfig(max_colors=32768, wire16=True)
    # without wire16 the int32 path is unconstrained
    RecolorConfig(max_colors=32768, wire16=False)
    ColorConfig(max_colors=32768, wire16=False)
