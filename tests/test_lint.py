"""Tests for the repro-lint static-analysis pass (src/repro/analysis).

Three layers of coverage:

1. Per-rule fixture tests: each rule has a positive fixture (every line
   marked ``# FIRE`` must produce exactly one finding of that rule, and
   no others) and a negative fixture (zero findings).  The fixtures
   double as executable documentation of what each rule means.
2. Mechanism tests: inline suppressions, the committed-baseline split,
   and finding rendering.
3. Self-check: ``src/repro/core`` and ``src/repro/kernels`` must lint
   completely clean with zero suppressions — the acceptance bar the CI
   lint job enforces.
"""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.analysis import (
    RULES,
    Finding,
    count_suppressions,
    lint_source,
    load_baseline,
    run_lint,
    split_baselined,
    write_baseline,
)

FIXTURES = Path(__file__).parent / "lint_fixtures"
REPO_ROOT = Path(__file__).resolve().parent.parent


def fire_lines(path: Path) -> set[int]:
    """Lines carrying a ``# FIRE`` marker — the golden finding list."""
    return {
        i
        for i, line in enumerate(path.read_text().splitlines(), start=1)
        if "# FIRE" in line
    }


def lint_fixture(name: str, virtual_path: str | None = None) -> list[Finding]:
    """Lint one fixture file with ALL rules enabled.

    ``virtual_path`` maps the fixture into a pretend repo location so
    path-scoped rules (host-sync, divergent-collective, nonuniform-loop
    hot-path scoping) see it as core/ code.
    """
    src = (FIXTURES / name).read_text()
    errors: list[str] = []
    findings = lint_source(src, virtual_path or name, errors=errors)
    assert not errors, f"lint errors on {name}: {errors}"
    return findings


# rule -> (positive fixture, negative fixture, virtual path prefix or None)
RULE_FIXTURES = {
    "key-reuse": ("key_reuse_pos.py", "key_reuse_neg.py", None),
    "id-overflow": ("id_overflow_pos.py", "id_overflow_neg.py", None),
    "host-sync": ("host_sync_pos.py", "host_sync_neg.py", "core"),
    "divergent-collective": (
        "divergent_collective_pos.py",
        "divergent_collective_neg.py",
        "core",
    ),
    "nonuniform-loop": (
        "nonuniform_loop_pos.py",
        "nonuniform_loop_neg.py",
        "core",
    ),
}


def _virtual(name: str, prefix: str | None) -> str | None:
    return f"{prefix}/{name}" if prefix else None


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_rule_fires_on_positive_fixture(rule):
    pos, _, prefix = RULE_FIXTURES[rule]
    findings = lint_fixture(pos, _virtual(pos, prefix))
    expected = fire_lines(FIXTURES / pos)
    assert expected, f"{pos} has no # FIRE markers"
    got = {(f.rule, f.line) for f in findings}
    want = {(rule, line) for line in expected}
    assert got == want, (
        f"{pos}: expected {rule} findings on lines {sorted(expected)}, "
        f"got {sorted(got)}"
    )


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_rule_quiet_on_negative_fixture(rule):
    _, neg, prefix = RULE_FIXTURES[rule]
    findings = lint_fixture(neg, _virtual(neg, prefix))
    assert findings == [], (
        f"{neg}: expected zero findings, got "
        f"{[f.render() for f in findings]}"
    )


def test_all_rules_have_fixtures():
    assert set(RULE_FIXTURES) == set(RULES)


def test_inline_suppression_silences_one_rule():
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.uniform(key, (4,))\n"
        "    b = jax.random.normal(key, (4,))  # repro-lint: disable=key-reuse\n"
        "    return a + b\n"
    )
    assert lint_source(src, "demo.py") == []
    # the same source without the pragma fires
    assert lint_source(src.replace("  # repro-lint: disable=key-reuse", ""),
                       "demo.py") != []
    assert count_suppressions(src) == 1


def test_suppression_is_rule_scoped():
    # a pragma for an unrelated rule does NOT silence the finding
    src = (
        "import jax\n"
        "def f(key):\n"
        "    a = jax.random.uniform(key, (4,))\n"
        "    b = jax.random.normal(key, (4,))  # repro-lint: disable=id-overflow\n"
        "    return a + b\n"
    )
    findings = lint_source(src, "demo.py")
    assert [f.rule for f in findings] == ["key-reuse"]


def test_baseline_roundtrip_and_split(tmp_path):
    f1 = Finding(path="a.py", line=3, rule="key-reuse", message="m1")
    f2 = Finding(path="b.py", line=9, rule="id-overflow", message="m2")
    bl = tmp_path / "baseline.json"
    write_baseline([f1], bl)
    keys = load_baseline(bl)
    assert f1.key() in keys and f2.key() not in keys
    new, old = split_baselined([f1, f2], keys)
    assert new == [f2] and old == [f1]
    # baseline matching ignores line numbers: the finding may drift
    drifted = Finding(path="a.py", line=30, rule="key-reuse", message="m1")
    new2, old2 = split_baselined([drifted], keys)
    assert new2 == [] and old2 == [drifted]


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == set()


def test_finding_render_is_clickable():
    f = Finding(path="core/x.py", line=7, rule="host-sync", message="boom")
    assert f.render() == "core/x.py:7: [host-sync] boom"


def test_committed_baseline_is_valid_and_empty():
    bl = REPO_ROOT / "tools" / "repro_lint_baseline.json"
    assert json.loads(bl.read_text()) == []


def test_core_and_kernels_lint_clean_with_zero_suppressions():
    """The acceptance bar: hot-path code carries no findings and no
    pragmas — uniformity contracts go through shard_uniform(), not
    suppressions."""
    targets = [
        str(REPO_ROOT / "src" / "repro" / "core"),
        str(REPO_ROOT / "src" / "repro" / "kernels"),
    ]
    result = run_lint(targets, root=str(REPO_ROOT))
    assert result.n_files > 0
    assert result.errors == []
    assert result.findings == [], [f.render() for f in result.findings]
    assert result.suppressed == 0

    suppression_count = sum(
        count_suppressions(p.read_text())
        for t in targets
        for p in Path(t).rglob("*.py")
    )
    assert suppression_count == 0


def test_full_src_tree_lints_clean():
    result = run_lint([str(REPO_ROOT / "src")], root=str(REPO_ROOT))
    assert result.errors == []
    assert result.findings == [], [f.render() for f in result.findings]
