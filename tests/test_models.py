"""Model substrate: attention/SSM math, MoE dispatch, smoke per arch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import NO_SHARDING, get_arch, list_archs, smoke_of
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import ParamDef
from repro.models.moe import capacity, moe_apply, moe_defs


def init_tree(defs, seed=0):
    return jax.tree.map(
        lambda d: d.initializer(jax.random.key(hash(d.shape) % 1000 + seed)),
        defs, is_leaf=lambda t: isinstance(t, ParamDef))


def naive_attention(q, k, v, causal):
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qh = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[3])


class TestBlockwise:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("sq,sk,h,hkv", [(64, 64, 4, 4), (64, 64, 4, 1),
                                             (96, 48, 4, 2)])
    def test_matches_naive(self, causal, sq, sk, h, hkv):
        r = np.random.default_rng(0)
        q = jnp.asarray(r.normal(0, 1, (2, sq, h, 16)), jnp.float32)
        k = jnp.asarray(r.normal(0, 1, (2, sk, hkv, 16)), jnp.float32)
        v = jnp.asarray(r.normal(0, 1, (2, sk, hkv, 16)), jnp.float32)
        if causal and sq != sk:
            pytest.skip("causal requires sq == sk in this test")
        got = attn._blockwise(q, k, v, causal=causal, scale=16 ** -0.5,
                              q_block=32, kv_block=16)
        want = naive_attention(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_block_size_invariance(self):
        r = np.random.default_rng(1)
        q = jnp.asarray(r.normal(0, 1, (1, 60, 2, 8)), jnp.float32)
        k = jnp.asarray(r.normal(0, 1, (1, 60, 2, 8)), jnp.float32)
        v = jnp.asarray(r.normal(0, 1, (1, 60, 2, 8)), jnp.float32)
        a = attn._blockwise(q, k, v, causal=True, scale=1.0, q_block=60,
                            kv_block=60)
        b = attn._blockwise(q, k, v, causal=True, scale=1.0, q_block=20,
                            kv_block=12)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestDecodeEquivalence:
    """prefill(S) + decode(1) == full forward over S+1 tokens."""

    @pytest.mark.parametrize("name", ["qwen3_0_6b", "minicpm3_4b",
                                      "rwkv6_1_6b", "jamba_v0_1_52b",
                                      "gemma_2b"])
    def test_decode_matches_forward(self, name):
        import dataclasses
        from repro.models.model import (backbone, decode_step, init_cache,
                                        param_defs, prefill, _unembed)
        cfg = smoke_of(get_arch(name))
        if cfg.is_moe:  # ample capacity: no token drops -> exact equivalence
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        params = init_tree(param_defs(cfg))
        r = np.random.default_rng(0)
        B, S = 2, 32
        toks = jnp.asarray(r.integers(0, cfg.vocab_size, (B, S + 1)),
                           jnp.int32)
        # full forward logits at position S (predicting token S+1)
        pos = jnp.arange(S + 1)[None]
        x, _, _ = backbone(params, toks, pos, cfg, NO_SHARDING, mode="train")
        want = _unembed(params, x[:, -1:], cfg, NO_SHARDING)
        # prefill on S tokens (cache capacity S+4), decode token S
        cache, _ = prefill(params, {"tokens": toks[:, :S]}, cfg, NO_SHARDING,
                           cache_len=S + 4)
        cache, got = decode_step(params, cache, toks[:, S:S + 1], cfg,
                                 NO_SHARDING)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-2, rtol=3e-2)


class TestRWKV6:
    def test_chunked_matches_stepwise(self):
        cfg = smoke_of(get_arch("rwkv6_1_6b"))
        defs = ssm.rwkv6_defs(cfg, "float32")
        p = init_tree(defs)
        r = np.random.default_rng(0)
        B, S, d = 2, 24, cfg.d_model
        x = jnp.asarray(r.normal(0, 1, (B, S, d)), jnp.float32)
        H = max(d // 64, 1)
        state0 = jnp.zeros((B, H, d // H, d // H), jnp.float32)
        xp0 = jnp.zeros((B, 1, d), jnp.float32)
        y_chunk, (xl, st) = ssm.rwkv6_chunked(p, x, xp0, state0, cfg,
                                              NO_SHARDING, chunk=8)
        # stepwise
        ys = []
        xp, st2 = xp0, state0
        for t in range(S):
            y, (xp, st2) = ssm.rwkv6_step(p, x[:, t:t + 1], xp, st2, cfg,
                                          NO_SHARDING)
            ys.append(y)
        y_step = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st2),
                                   atol=1e-3, rtol=1e-3)

    def test_chunk_size_invariance(self):
        cfg = smoke_of(get_arch("rwkv6_1_6b"))
        p = init_tree(ssm.rwkv6_defs(cfg, "float32"))
        r = np.random.default_rng(1)
        B, S, d = 1, 32, cfg.d_model
        x = jnp.asarray(r.normal(0, 1, (B, S, d)), jnp.float32)
        H = max(d // 64, 1)
        st0 = jnp.zeros((B, H, d // H, d // H), jnp.float32)
        xp0 = jnp.zeros((B, 1, d), jnp.float32)
        y1, _ = ssm.rwkv6_chunked(p, x, xp0, st0, cfg, NO_SHARDING, chunk=4)
        y2, _ = ssm.rwkv6_chunked(p, x, xp0, st0, cfg, NO_SHARDING, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3,
                                   rtol=1e-3)


class TestMamba:
    def test_streaming_matches_full(self):
        cfg = smoke_of(get_arch("jamba_v0_1_52b"))
        p = init_tree(ssm.mamba_defs(cfg, "float32"))
        r = np.random.default_rng(0)
        B, S, d = 2, 16, cfg.d_model
        di = cfg.expand * d
        x = jnp.asarray(r.normal(0, 1, (B, S, d)), jnp.float32)
        conv0 = jnp.zeros((B, cfg.d_conv - 1, di), jnp.float32)
        h0 = jnp.zeros((B, di, cfg.d_state), jnp.float32)
        y_full, _ = ssm.mamba_apply(p, x, conv0, h0, cfg, NO_SHARDING)
        # streaming one token at a time
        ys, conv, h = [], conv0, h0
        for t in range(S):
            y, (conv, h) = ssm.mamba_apply(p, x[:, t:t + 1], conv, h, cfg,
                                           NO_SHARDING)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), atol=1e-4, rtol=1e-4)


class TestMoE:
    def test_dispatch_combines_expert_outputs(self):
        cfg = smoke_of(get_arch("moonshot_v1_16b_a3b"))
        p = init_tree(moe_defs(cfg, "float32"))
        r = np.random.default_rng(0)
        x = jnp.asarray(r.normal(0, 0.5, (2, 16, cfg.d_model)), jnp.float32)
        y, aux = moe_apply(p, x, cfg, NO_SHARDING)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()
        assert float(aux) > 0

    def test_capacity_bounds(self):
        cfg = smoke_of(get_arch("deepseek_v3_671b"))
        c = capacity(1024, cfg)
        assert c >= 1024 * cfg.n_experts_per_tok // cfg.n_experts
        assert c % 8 == 0

    def test_moe_matches_dense_when_capacity_ample(self):
        """With huge capacity, sort-based dispatch == direct per-token mix."""
        cfg = smoke_of(get_arch("moonshot_v1_16b_a3b"))
        cfg = cfg.__class__(**{**cfg.__dict__, "capacity_factor": 8.0})
        p = init_tree(moe_defs(cfg, "float32"))
        r = np.random.default_rng(0)
        x = jnp.asarray(r.normal(0, 0.5, (1, 8, cfg.d_model)), jnp.float32)
        y, _ = moe_apply(p, x, cfg, NO_SHARDING)
        # direct reference
        T, d = 8, cfg.d_model
        xf = x.reshape(T, d)
        logits = xf @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        g, idx = jax.lax.top_k(probs, cfg.n_experts_per_tok)
        g = g / g.sum(-1, keepdims=True)
        want = np.zeros((T, d), np.float32)
        eg = p["experts"]
        for t in range(T):
            for j in range(cfg.n_experts_per_tok):
                e = int(idx[t, j])
                h = jax.nn.silu(xf[t] @ eg["w_gate"][e]) * (xf[t] @ eg["w_up"][e])
                want[t] += float(g[t, j]) * np.asarray(h @ eg["w_down"][e])
        sh = p["shared"]
        want += np.asarray(jax.nn.silu(xf @ sh["w_gate"]) * (xf @ sh["w_up"])
                           @ sh["w_down"])
        np.testing.assert_allclose(np.asarray(y.reshape(T, d)), want,
                                   atol=2e-4, rtol=2e-3)


class TestMLA:
    def test_absorbed_decode_matches_materialized(self):
        """MLA decode (latent cache, absorbed matmuls) == naive K/V path."""
        cfg = smoke_of(get_arch("deepseek_v3_671b"))
        p = init_tree(attn.mla_defs(cfg, "float32"))
        r = np.random.default_rng(0)
        B, S, d = 2, 12, cfg.d_model
        x = jnp.asarray(r.normal(0, 1, (B, S + 1, d)), jnp.float32)
        pos = jnp.arange(S + 1)[None]
        # full materialized forward, last position
        o_full, _ = attn.mla_apply(p, x, pos, cfg, NO_SHARDING, mode="train")
        # prefill + absorbed decode of the last token
        cache = {
            "c_kv": jnp.zeros((B, S + 2, cfg.kv_lora_rank), jnp.float32),
            "k_rope": jnp.zeros((B, S + 2, cfg.qk_rope_dim), jnp.float32)}
        _, cache1 = attn.mla_apply(p, x[:, :S], pos[:, :S], cfg, NO_SHARDING,
                                   mode="prefill", cache=cache)
        o_dec, _ = attn.mla_apply(p, x[:, S:S + 1], pos[:, S:S + 1], cfg,
                                  NO_SHARDING, mode="decode", cache=cache1,
                                  cache_pos=jnp.int32(S))
        np.testing.assert_allclose(np.asarray(o_dec[:, 0]),
                                   np.asarray(o_full[:, S]), atol=2e-3,
                                   rtol=2e-3)


@pytest.mark.parametrize("name", list_archs())
def test_arch_smoke_train_step(name):
    """Reduced config: one forward/loss on CPU, finite, right shapes."""
    from repro.models.model import loss_fn, param_defs
    cfg = smoke_of(get_arch(name))
    params = init_tree(param_defs(cfg))
    r = np.random.default_rng(0)
    B, S = 2, 32
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            r.normal(0, 1, (B, cfg.enc_len, cfg.d_model)), jnp.float32)
    if cfg.n_patches:
        batch["patch_embeds"] = jnp.asarray(
            r.normal(0, 0.02, (B, cfg.n_patches, cfg.d_model)), jnp.float32)
        batch["pos3"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    loss, metrics = jax.jit(
        lambda p, b: loss_fn(p, b, cfg, NO_SHARDING))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: loss_fn(p, batch, cfg, NO_SHARDING)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
