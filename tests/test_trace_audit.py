"""The jaxpr collective audit (repro.analysis.trace_audit) as a test.

One full ``run_trace_audit`` pass at P=2 — the same entry point the CI
lint job drives — plus unit coverage of the jaxpr walkers it's built on.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.trace_audit import (CALLBACK_PRIMS, COLLECTIVE_PRIMS,
                                        TraceAudit, callback_prims,
                                        collective_sequence, prim_sequence,
                                        run_trace_audit)


def test_prim_sequence_recurses_into_control_flow():
    def fn(x):
        def body(i, c):
            return c + jax.lax.psum(x, "workers")
        pred = jax.lax.pmax(jnp.sum(x), "workers") > 0
        c = jax.lax.cond(pred, lambda v: v * 2, lambda v: v, x)
        return jax.lax.fori_loop(0, 3, body, c)

    jx = jax.make_jaxpr(fn, axis_env=[("workers", 2)])(
        jax.ShapeDtypeStruct((4,), jnp.int32))
    seq = collective_sequence(jx)
    # pmax at top level, psum inside the fori (while) body sub-jaxpr
    assert "pmax" in seq and "psum" in seq
    assert set(seq) <= COLLECTIVE_PRIMS


def test_callback_prims_detected():
    import numpy as np

    def fn(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((4,), jnp.int32), x)

    jx = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4,), jnp.int32))
    cbs = callback_prims(jx)
    assert cbs and set(cbs) <= CALLBACK_PRIMS


def test_clean_program_has_no_callbacks():
    jx = jax.make_jaxpr(lambda x: jnp.sum(x * 2))(
        jax.ShapeDtypeStruct((4,), jnp.int32))
    assert callback_prims(jx) == ()
    assert "mul" in prim_sequence(jx)


def test_audit_record_and_summary():
    a = TraceAudit()
    a.record("x", True, "fine")
    a.record("y", False, "broke")
    assert not a.ok
    assert a.failures == ["y: broke"]
    lines = a.summary_lines()
    assert lines[0].endswith("1 check(s) passed, 1 failure(s)")


@pytest.mark.slow
def test_full_trace_audit_passes():
    """The CI contract: every audited invariant holds at P=2."""
    audit = run_trace_audit(P=2)
    assert audit.ok, "\n".join(audit.summary_lines())
    names = {name for name, _ in audit.checks}
    assert {"no-host-callbacks", "shard-uniform-sequence",
            "batch-invariant-sequence", "auto-resolves-identically",
            "one-compile-per-signature"} <= names
