"""Graph substrate: generators, partitioning invariants (unit + property)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests
from hypothesis import given, settings, strategies as st

from repro.core import Graph, partition_graph, rmat


def random_graph(n, p, seed):
    g = np.random.default_rng(seed).random((n, n)) < p
    g = np.triu(g, 1)
    g = g | g.T
    src, dst = np.nonzero(g)
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    return Graph(n, np.cumsum(indptr), dst.astype(np.int32))


class TestGenerators:
    def test_rmat_symmetric_no_selfloops(self):
        g = rmat.rmat_good(8, 8, seed=2)
        src = np.repeat(np.arange(g.n), g.degrees)
        assert (src != g.indices).all()
        # symmetry: edge set equals its transpose
        fwd = set(zip(src.tolist(), g.indices.tolist()))
        assert all((v, u) in fwd for u, v in fwd)

    def test_grid_degrees(self):
        g = rmat.grid2d(5, 5, 9)
        assert g.n == 25
        assert g.max_degree == 8  # interior of a 9-pt stencil
        g5 = rmat.grid2d(5, 5, 5)
        assert g5.max_degree == 4

    def test_grid3d(self):
        g = rmat.grid3d(4, 4, 4)
        assert g.n == 64
        assert g.max_degree == 26

    def test_suites_build(self):
        for name, fn in {**rmat.SUITE_REAL}.items():
            if "geom" in name:
                continue
            g = fn()
            assert g.n > 0 and g.m > 0

    def test_dedup_survives_scale32_coordinates(self):
        """Edge dedup at the int64-packing overflow boundary (scale >= 32).

        The former ``u * n + v`` int64 key wraps for n = 2**32 endpoints and
        decodes to negative vertices; the lexsort dedup must handle ids past
        2**31 exactly.
        """
        u = np.array([2**31, 2**31, 2**31 + 1, 0, 2**32 - 1], np.int64)
        v = np.array([5, 5, 7, 2**32 - 1, 0], np.int64)
        uu, vv = rmat._dedup_edges(u, v)
        assert list(zip(uu.tolist(), vv.tolist())) == [
            (0, 2**32 - 1), (2**31, 5), (2**31 + 1, 7), (2**32 - 1, 0)]
        # and stays identical to np.unique-packed keys in the safe range
        rng = np.random.default_rng(0)
        a = rng.integers(0, 1000, 500)
        b = rng.integers(0, 1000, 500)
        key = np.unique(a * 1000 + b)
        uu, vv = rmat._dedup_edges(a, b)
        np.testing.assert_array_equal(uu * 1000 + vv, key)


class TestPartition:
    @pytest.mark.parametrize("P", [1, 2, 3, 7, 8])
    def test_edges_preserved(self, P):
        g = rmat.rmat_er(8, 8, seed=1)
        pg = partition_graph(g, P)
        # reconstruct global adjacency from per-proc CSR
        edges = set()
        for p in range(pg.P):
            nl = int(pg.n_local[p])
            for v in range(nl):
                gv = pg.gvid[p, v]
                for e in range(pg.indptr[p, v], pg.indptr[p, v + 1]):
                    slot = pg.indices[p, e]
                    gu = pg.gvid[p, slot]
                    assert gu >= 0
                    edges.add((int(gv), int(gu)))
        src = np.repeat(np.arange(g.n), g.degrees)
        truth = set(zip(src.tolist(), g.indices.tolist()))
        assert edges == truth

    def test_ghost_maps(self):
        g = rmat.grid2d(16, 16, 9)
        pg = partition_graph(g, 4)
        for p in range(4):
            for gi in range(int(pg.n_ghost[p])):
                owner = pg.ghost_owner[p, gi]
                slot = pg.ghost_slot[p, gi]
                gvid = pg.gvid[p, pg.n_local_max + gi]
                # the owner's boundary list at `slot` is exactly this vertex
                bnd_local = pg.boundary[owner, slot]
                assert pg.gvid[owner, bnd_local] == gvid

    def test_internal_flags(self):
        g = rmat.grid2d(16, 16, 5)
        pg = partition_graph(g, 4)
        for p in range(4):
            nl = int(pg.n_local[p])
            for v in range(nl):
                remote = any(pg.indices[p, e] >= pg.n_local_max
                             for e in range(pg.indptr[p, v],
                                            pg.indptr[p, v + 1]))
                assert pg.is_internal[p, v] == (not remote)

    @pytest.mark.parametrize("P", [2, 3, 4])
    def test_two_hop_halo_matches_oracle(self, P):
        """halo=2: nbr2 rows, ghost tables and boundary vs a brute force."""
        g = rmat.rmat_good(8, 8, seed=1)
        adj = [set(g.indices[g.indptr[v]:g.indptr[v + 1]].tolist())
               for v in range(g.n)]
        d2 = []
        for v in range(g.n):
            s = set()
            for w in adj[v]:
                s |= adj[w]
            d2.append(s - adj[v] - {v})
        pg = partition_graph(g, P, halo=2)
        for p in range(P):
            lo, hi = int(pg.offs[p]), int(pg.offs[p + 1])
            nl = int(pg.n_local[p])
            for v in range(0, nl, 7):                   # sampled rows
                row = pg.nbr2[p, v]
                slots = row[row != pg.sentinel]
                assert set(pg.gvid[p, slots].tolist()) == d2[lo + v]
            assert (pg.nbr2[p, nl:] == pg.sentinel).all()
            # ghost set = all remote vertices within two hops, ascending
            want = set()
            for v in range(lo, hi):
                want |= {u for u in adj[v] | d2[v] if not lo <= u < hi}
            ng = int(pg.n_ghost[p])
            got = pg.gvid[p, pg.n_local_max : pg.n_local_max + ng]
            assert got.tolist() == sorted(want)
            # boundary = locals read by some other shard (within two hops)
            bnd = set(pg.boundary[p, : int(pg.n_boundary[p])].tolist())
            for v in range(nl):
                read = any(not lo <= u < hi for u in adj[lo + v] | d2[lo + v])
                assert (v in bnd) == read
                assert pg.is_internal[p, v] == (not read)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(6, 40), p=st.floats(0.05, 0.5),
           P=st.integers(1, 5), seed=st.integers(0, 99))
    def test_partition_roundtrip_property(self, n, p, P, seed):
        g = random_graph(n, p, seed)
        pg = partition_graph(g, P)
        assert int(pg.n_local.sum()) == n
        # every cross edge appears on both sides
        total_edges = 0
        for q in range(P):
            nl = int(pg.n_local[q])
            total_edges += int(pg.indptr[q, nl])
        assert total_edges == g.m_directed
