"""Piggybacking message accounting (§3.1 / Fig. 4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ColorConfig, color_graph_sim, colors_from_views,
                        compute_order, message_stats, ordering,
                        partition_graph, rmat)
from repro.core.comm import AxisComm
from repro.core.recolor import class_sizes, permutation_rank


def _setup(P=8):
    g = rmat.grid2d(32, 32, 9)
    pg = partition_graph(g, P)
    order = compute_order(pg, ordering.NATURAL)
    # paper-faithful sequential supersteps: the message-count study mirrors
    # the paper's Fig. 4 setup, whose seed coloring is the sequential one
    view, _ = color_graph_sim(pg, order, ColorConfig(max_colors=64,
                                                     superstep=64,
                                                     parallel_chunk=False))
    colors = colors_from_views(pg, np.asarray(view))
    sizes = np.bincount(colors, minlength=64).astype(np.int32)
    sizes[0] = 0
    rank = np.asarray(permutation_rank(jnp.asarray(sizes), "nd",
                                       jax.random.key(0)))
    return g, pg, colors, rank


def test_message_stats_invariants():
    g, pg, colors, rank = _setup()
    ms = message_stats(pg, colors, rank)
    assert ms.base_total == ms.base_nonempty + ms.base_empty
    assert ms.pig_total <= ms.base_nonempty  # piggybacking merges, never adds
    assert ms.pig_total >= ms.n_pairs // 2   # every dependent pair sends >=1
    assert 0.0 <= ms.message_reduction <= 1.0
    assert ms.collective_steps_pig <= ms.collective_steps_base


def test_piggyback_removes_empty_messages():
    """Paper Fig. 1/4: all empty messages disappear under piggybacking."""
    g, pg, colors, rank = _setup()
    ms = message_stats(pg, colors, rank)
    assert ms.base_empty > 0          # the base scheme wastes messages
    # piggybacked count excludes every empty message by construction
    assert ms.pig_total <= ms.base_total - ms.base_empty


def test_more_processors_more_savings():
    g = rmat.grid2d(48, 48, 9)
    reductions = []
    for P in (2, 8):
        pg = partition_graph(g, P)
        order = compute_order(pg, ordering.NATURAL)
        view, _ = color_graph_sim(pg, order, ColorConfig(max_colors=64,
                                                         superstep=64,
                                                         parallel_chunk=False))
        colors = colors_from_views(pg, np.asarray(view))
        sizes = np.bincount(colors, minlength=64).astype(np.int32)
        sizes[0] = 0
        rank = np.asarray(permutation_rank(jnp.asarray(sizes), "nd",
                                           jax.random.key(0)))
        ms = message_stats(pg, colors, rank)
        reductions.append(ms.message_reduction)
    assert all(r > 0 for r in reductions)
