"""Optimizer, checkpointing, fault tolerance, gradient compression."""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.compression import (compressed_psum_tree, dequantize_int8,
                                     quantize_int8, wire_bytes)
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   lr_at)


class TestOptimizer:
    def test_adamw_matches_numpy_reference(self):
        cfg = OptConfig(peak_lr=1e-2, warmup_steps=0, total_steps=1000,
                        weight_decay=0.0, grad_clip=1e9)
        p = {"w": jnp.asarray(np.ones((3, 3), np.float32))}
        g = {"w": jnp.asarray(np.full((3, 3), 0.5, np.float32))}
        st = init_opt_state(p, cfg)
        new_p, st, info = adamw_update(p, g, st, cfg)
        # reference
        m = 0.1 * 0.5
        v = 0.05 * 0.25
        lr = float(lr_at(jnp.int32(1), cfg))
        step = lr * (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.95)) + cfg.eps)
        np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - step,
                                   rtol=1e-5)

    def test_grad_clip(self):
        cfg = OptConfig(grad_clip=1.0, warmup_steps=0)
        p = {"w": jnp.zeros((4,), jnp.float32)}
        g = {"w": jnp.full((4,), 100.0)}
        st = init_opt_state(p, cfg)
        _, _, info = adamw_update(p, g, st, cfg)
        assert float(info["grad_norm"]) == pytest.approx(200.0)

    def test_lr_schedule(self):
        cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                        min_lr_frac=0.1)
        assert float(lr_at(jnp.int32(5), cfg)) == pytest.approx(0.5)
        assert float(lr_at(jnp.int32(10), cfg)) == pytest.approx(1.0, rel=1e-3)
        assert float(lr_at(jnp.int32(110), cfg)) == pytest.approx(0.1,
                                                                  rel=1e-3)

    def test_weight_decay_only_on_matrices(self):
        cfg = OptConfig(peak_lr=1e-2, warmup_steps=0, weight_decay=1.0,
                        grad_clip=1e9)
        p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        st = init_opt_state(p, cfg)
        new_p, _, _ = adamw_update(p, g, st, cfg)
        assert float(new_p["w"][0, 0]) < 1.0   # decayed
        assert float(new_p["b"][0]) == 1.0     # not decayed


class TestCheckpoint:
    def _tree(self, seed=0):
        r = np.random.default_rng(seed)
        return {"params": {"a": r.normal(size=(4, 4)).astype(np.float32),
                           "nested": {"b": r.integers(0, 9, 7)}},
                "opt": {"count": np.int32(3)}}

    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as td:
            tree = self._tree()
            ckpt.save(td, 7, tree)
            step, back = ckpt.restore(td)
            assert step == 7
            np.testing.assert_array_equal(back["params"]["a"],
                                          tree["params"]["a"])
            np.testing.assert_array_equal(back["params"]["nested"]["b"],
                                          tree["params"]["nested"]["b"])

    def test_corruption_falls_back_to_older(self):
        with tempfile.TemporaryDirectory() as td:
            ckpt.save(td, 1, self._tree(1))
            ckpt.save(td, 2, self._tree(2))
            # corrupt newest
            victim = Path(td) / "step_00000002" / "params.a.npy"
            data = bytearray(victim.read_bytes())
            data[-1] ^= 0xFF
            victim.write_bytes(bytes(data))
            assert ckpt.latest_step(td) == 1

    def test_gc_keeps_last_n(self):
        with tempfile.TemporaryDirectory() as td:
            for s in range(5):
                ckpt.save(td, s, self._tree(s), keep=2)
            dirs = sorted(p.name for p in Path(td).iterdir())
            assert dirs == ["step_00000003", "step_00000004"]

    def test_async_save(self):
        with tempfile.TemporaryDirectory() as td:
            t = ckpt.save_async(td, 11, self._tree())
            t.join()
            assert ckpt.latest_step(td) == 11


class TestCompression:
    def test_quantize_bounds(self, rng):
        x = jnp.asarray(rng.normal(0, 3, (64, 64)), jnp.float32)
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
        assert err.max() <= float(s) * 0.5 + 1e-6

    def test_ef_allreduce_preserves_mean_over_time(self, rng):
        """Error feedback: accumulated compressed means converge to truth."""
        P = 4
        gs = jnp.asarray(rng.normal(0, 1, (P, 32)), jnp.float32)

        def step(g, err):
            out, new_err = compressed_psum_tree({"g": g}, {"g": err}, "dp")
            return out["g"], new_err["g"]

        f = jax.vmap(step, axis_name="dp")
        err = jnp.zeros((P, 32))
        acc = jnp.zeros((P, 32))
        T = 50
        for _ in range(T):
            out, err = f(gs, err)
            acc = acc + out
        true_mean = gs.mean(0, keepdims=True)
        np.testing.assert_allclose(np.asarray(acc / T),
                                   np.broadcast_to(np.asarray(true_mean),
                                                   (P, 32)),
                                   atol=2e-3)

    def test_wire_savings(self):
        tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((10, 10))}
        full, comp = wire_bytes(tree)
        assert full == 4 * 1100
        assert comp < full / 3.9


class TestTrainerIntegration:
    @pytest.mark.slow
    def test_loss_decreases_and_failure_recovery(self):
        from repro.configs import get_arch, plan_for_mesh, smoke_of
        from repro.data.pipeline import DataConfig
        from repro.launch.mesh import make_local_mesh
        from repro.train import (FailureInjector, OptConfig, Trainer,
                                 TrainerConfig)
        arch = smoke_of(get_arch("qwen3_0_6b"))
        mesh = make_local_mesh()
        plan = plan_for_mesh(mesh)
        data = DataConfig(vocab_size=arch.vocab_size, seq_len=64,
                          global_batch=8)
        with tempfile.TemporaryDirectory() as td:
            tr = Trainer(arch, mesh, plan, data,
                         OptConfig(peak_lr=1e-3, warmup_steps=10,
                                   total_steps=80),
                         TrainerConfig(num_steps=80, ckpt_every=20,
                                       ckpt_dir=td, log_every=20,
                                       async_ckpt=False),
                         injector=FailureInjector(fail_at=(30,)))
            tr.run()
            losses = [h["loss"] for h in tr.history]
            assert tr.restarts == 1
            assert losses[-1] < losses[0] * 0.5
