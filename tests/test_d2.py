"""Distance-2 coloring over the two-hop halo: validity, goldens, equivalence.

The acceptance matrix of the D2 subsystem: for grids + all three RMAT
classes at P in {2, 4, 16}, the distributed D2 coloring must

  - carry zero distance-2 conflicts (``check_coloring(distance=2)``),
  - be bitwise-identical across the sparse / all-gather exchange schemes,
  - be bitwise-identical across the xla / pallas-interpret backends,
  - match the golden (n_colors, sha) pins below.

``tile=16`` bounds intra-tile speculative conflicts: inside one tile every
member of a distance-2 clique (e.g. a hub's neighbourhood) sees the same
forbidden set and picks the same first-fit color, so progress per round per
clique is one vertex *per tile* — small tiles keep skewed RMAT graphs
converging in tens of rounds (DESIGN.md §5).
"""
import hashlib
from functools import lru_cache

import jax
import numpy as np
import pytest

from repro.core import (ColorConfig, RecolorConfig, check_coloring,
                        color_graph_sim, colors_from_views, compute_order,
                        ordering, partition_graph, recolor_sim, rmat)

GRAPHS = {
    "grid2d": lambda: rmat.grid2d(12, 12, 9),
    "grid3d": lambda: rmat.grid3d(6, 6, 6),
    "rmat_er": lambda: rmat.rmat_er(8, 8, seed=1),
    "rmat_good": lambda: rmat.rmat_good(8, 8, seed=1),
    "rmat_bad": lambda: rmat.rmat_bad(8, 8, seed=1),
}
P_SWEEP = (2, 4, 16)

CFG = dict(max_colors=512, superstep=64, tile=16, max_rounds=256, seed=0,
           distance=2)


def _hash(colors: np.ndarray) -> str:
    return hashlib.sha256(colors.astype(np.int32).tobytes()).hexdigest()[:16]


@lru_cache(maxsize=None)
def _graph(gname):
    return GRAPHS[gname]()


@lru_cache(maxsize=None)
def _pgraph(gname, P):
    return partition_graph(_graph(gname), P, halo=2)


@lru_cache(maxsize=None)
def _color_d2(gname, P, scheme="sparse", backend="xla"):
    pg = _pgraph(gname, P)
    order = compute_order(pg, ordering.NATURAL)
    cfg = ColorConfig(scheme=scheme, backend=backend, **CFG)
    view, stats = color_graph_sim(pg, order, cfg)
    return np.asarray(view), stats


def _assert_views_equal(pg, va, vb):
    """Bitwise equality over local slots + each shard's real ghosts."""
    np.testing.assert_array_equal(va[:, : pg.n_local_max],
                                  vb[:, : pg.n_local_max])
    for p in range(pg.P):
        ng = int(pg.n_ghost[p])
        np.testing.assert_array_equal(
            va[p, pg.n_local_max : pg.n_local_max + ng],
            vb[p, pg.n_local_max : pg.n_local_max + ng])


# (gname, P) -> (n_colors, sha16) of the sparse/xla D2 coloring.
D2_GOLD = {
    ("grid2d", 2): (15, "448c19943ff1f812"),
    ("grid2d", 4): (15, "b6c120b743514b90"),
    ("grid2d", 16): (14, "e867c988e04b521f"),
    ("grid3d", 2): (41, "69b5d0621b1c0650"),
    ("grid3d", 4): (40, "54d91afa1c37e30f"),
    ("grid3d", 16): (39, "25e1d8add1b79810"),
    ("rmat_er", 2): (64, "5f511f8598f9f47c"),
    ("rmat_er", 4): (63, "93a9146971130836"),
    ("rmat_er", 16): (63, "d0bc78a755459e25"),
    ("rmat_good", 2): (67, "71ed9af071a5446c"),
    ("rmat_good", 4): (65, "8fc404023e6013a8"),
    ("rmat_good", 16): (65, "3120609686f71fdd"),
    ("rmat_bad", 2): (82, "ca0b4a9c55621082"),
    ("rmat_bad", 4): (83, "076b557e3613881c"),
    ("rmat_bad", 16): (82, "f82f163cbf4a7166"),
}


@pytest.mark.parametrize("P", P_SWEEP)
@pytest.mark.parametrize("gname", list(GRAPHS))
def test_d2_valid_and_golden(gname, P):
    g = _graph(gname)
    pg = _pgraph(gname, P)
    view, stats = _color_d2(gname, P)
    colors = colors_from_views(pg, view)
    st = check_coloring(g, colors, distance=2)
    assert st["valid"], st
    assert st["n_colors"] == stats["n_colors"]
    want_nc, want_hash = D2_GOLD[(gname, P)]
    assert stats["n_colors"] == want_nc
    assert _hash(colors) == want_hash


@pytest.mark.parametrize("P", P_SWEEP)
@pytest.mark.parametrize("gname", list(GRAPHS))
def test_d2_sparse_equals_allgather(gname, P):
    pg = _pgraph(gname, P)
    va, _ = _color_d2(gname, P, scheme="sparse")
    vb, _ = _color_d2(gname, P, scheme="allgather")
    _assert_views_equal(pg, va, vb)


@pytest.mark.parametrize("P", P_SWEEP)
@pytest.mark.parametrize("gname", list(GRAPHS))
def test_d2_xla_equals_pallas(gname, P):
    pg = _pgraph(gname, P)
    va, sa = _color_d2(gname, P, backend="xla")
    vb, sb = _color_d2(gname, P, backend="pallas")
    _assert_views_equal(pg, va, vb)
    assert sa["n_colors"] == sb["n_colors"]


def test_d2_sequential_mode_valid():
    """The paper-faithful scalar loop honors the two-hop constraint too."""
    g, P = _graph("rmat_good"), 4
    pg = _pgraph("rmat_good", P)
    order = compute_order(pg, ordering.NATURAL)
    cfg = ColorConfig(parallel_chunk=False, **CFG)
    view, _ = color_graph_sim(pg, order, cfg)
    st = check_coloring(g, colors_from_views(pg, np.asarray(view)),
                        distance=2)
    assert st["valid"], st


def test_d1_on_halo2_partition_matches_halo1():
    """The wider halo changes comm structure, never D1 colorings."""
    g = _graph("rmat_good")
    pg1 = partition_graph(g, 4, halo=1)
    pg2 = _pgraph("rmat_good", 4)
    order1 = compute_order(pg1, ordering.NATURAL)
    order2 = compute_order(pg2, ordering.NATURAL)
    cfg = ColorConfig(max_colors=512, superstep=64, seed=0)
    v1, _ = color_graph_sim(pg1, order1, cfg)
    v2, _ = color_graph_sim(pg2, order2, cfg)
    np.testing.assert_array_equal(colors_from_views(pg1, np.asarray(v1)),
                                  colors_from_views(pg2, np.asarray(v2)))


class TestD2Recolor:
    @pytest.fixture(scope="class")
    def seeded(self):
        gname, P = "rmat_good", 4
        view, stats = _color_d2(gname, P)
        return _graph(gname), _pgraph(gname, P), view, stats

    @pytest.mark.parametrize("perm", ["rv", "ni", "nd", "rand"])
    def test_permutations_valid_and_no_worse(self, seeded, perm):
        g, pg, view, stats = seeded
        cfg = RecolorConfig(max_colors=512, distance=2)
        v2, st = recolor_sim(pg, view, perm, cfg, key=jax.random.key(11))
        colors = colors_from_views(pg, np.asarray(v2))
        chk = check_coloring(g, colors, distance=2)
        assert chk["valid"], chk
        assert st["n_colors"] <= stats["n_colors"]

    def test_piggyback_equals_per_step(self, seeded):
        """The D2 dep sources (CSR + two-hop ELL) defer no needed round."""
        g, pg, view, _ = seeded
        key = jax.random.key(3)
        v_pig, st_pig = recolor_sim(pg, view, "nd", RecolorConfig(
            max_colors=512, distance=2, piggyback=True), key=key)
        v_all, st_all = recolor_sim(pg, view, "nd", RecolorConfig(
            max_colors=512, distance=2, piggyback=False), key=key)
        _assert_views_equal(pg, np.asarray(v_pig), np.asarray(v_all))
        assert st_pig["n_exchanges"] <= st_all["n_exchanges"]

    def test_scheme_equivalence(self, seeded):
        g, pg, view, _ = seeded
        key = jax.random.key(5)
        va, _ = recolor_sim(pg, view, "nd", RecolorConfig(
            max_colors=512, distance=2, scheme="allgather"), key=key)
        vs, _ = recolor_sim(pg, view, "nd", RecolorConfig(
            max_colors=512, distance=2, scheme="sparse"), key=key)
        _assert_views_equal(pg, np.asarray(va), np.asarray(vs))


class TestPartialD2:
    """Bipartite partial coloring: only a marked subset is constrained."""

    def _marked(self, g, pg):
        marked_g = np.arange(g.n) % 2 == 0          # "column" vertices
        marked = np.zeros((pg.P, pg.n_local_max), bool)
        for p in range(pg.P):
            nl, lo = int(pg.n_local[p]), int(pg.offs[p])
            marked[p, :nl] = marked_g[lo : lo + nl]
        return marked_g, marked

    @pytest.mark.parametrize("gname", ["grid2d", "rmat_good"])
    def test_partial_d2_valid(self, gname):
        g, P = _graph(gname), 4
        pg = _pgraph(gname, P)
        marked_g, marked = self._marked(g, pg)
        order = compute_order(pg, ordering.NATURAL)
        cfg = ColorConfig(partial=True, **CFG)
        view, stats = color_graph_sim(pg, order, cfg, marked=marked)
        colors = colors_from_views(pg, np.asarray(view))
        assert (colors[~marked_g] == 0).all()        # untouched subset
        chk = check_coloring(g, colors, distance=2, marked=marked_g)
        assert chk["valid"], chk
        # partial never needs more colors than the full D2 coloring
        _, full = _color_d2(gname, P)
        assert stats["n_colors"] <= full["n_colors"]

    def test_partial_requires_marked(self):
        pg = _pgraph("grid2d", 2)
        order = compute_order(pg, ordering.NATURAL)
        with pytest.raises(AssertionError):
            color_graph_sim(pg, order, ColorConfig(partial=True, **CFG))

    def test_partial_then_recolor(self):
        """RC on a partial coloring recolors only the marked classes.

        No flag needed: unmarked vertices are class 0, which the step loop
        skips unconditionally.
        """
        g, P = _graph("grid2d"), 4
        pg = _pgraph("grid2d", P)
        marked_g, marked = self._marked(g, pg)
        order = compute_order(pg, ordering.NATURAL)
        view, _ = color_graph_sim(pg, order,
                                  ColorConfig(partial=True, **CFG),
                                  marked=marked)
        cfg = RecolorConfig(max_colors=512, distance=2)
        v2, _ = recolor_sim(pg, view, "nd", cfg, key=jax.random.key(2))
        colors = colors_from_views(pg, np.asarray(v2))
        assert (colors[~marked_g] == 0).all()
        chk = check_coloring(g, colors, distance=2, marked=marked_g)
        assert chk["valid"], chk


def test_distance2_requires_halo2():
    g = _graph("grid2d")
    pg = partition_graph(g, 2, halo=1)
    order = compute_order(pg, ordering.NATURAL)
    with pytest.raises(ValueError, match="halo=2"):
        color_graph_sim(pg, order, ColorConfig(**CFG))


def test_validator_negative_sentinel_no_crash():
    """A leaked -1 sentinel color must report, not raise (np.bincount)."""
    g = _graph("grid2d")
    colors = np.ones(g.n, np.int32)
    colors[5] = -1
    for distance in (1, 2):
        st = check_coloring(g, colors, distance=distance)
        assert not st["valid"]
        assert st["n_uncolored"] == 1
