"""Coloring-based scheduling (paper tie-in) + loop-aware roofline parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests
from hypothesis import given, settings, strategies as st

from repro.data.coloring_sched import (conflict_graph, schedule,
                                       validate_schedule)
from repro.roofline import analyze_hlo, roofline_terms


class TestScheduling:
    def test_schedule_is_conflict_free(self, rng):
        res = rng.integers(0, 30, (64, 3))
        groups, n_groups, _ = schedule(res, 64, n_workers=2)
        assert validate_schedule(res, groups)
        assert sum(len(g) for g in groups) == 64

    def test_fewer_groups_than_sequential(self, rng):
        res = rng.integers(0, 100, (128, 2))
        groups, n_groups, _ = schedule(res, 128, n_workers=4)
        assert n_groups < 128  # coloring beats fully-serial execution
        g = conflict_graph(res, 128)
        assert n_groups <= g.max_degree + 1

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(4, 40), r=st.integers(1, 3), seed=st.integers(0, 99))
    def test_schedule_property(self, n, r, seed):
        res = np.random.default_rng(seed).integers(0, 12, (n, r))
        groups, _, _ = schedule(res, n, n_workers=1,
                                use_quality_preset=False)
        assert validate_schedule(res, groups)


class TestRooflineParser:
    def test_scan_trip_counts_accounted(self):
        """Scanned and unrolled versions must parse to ~equal FLOPs."""
        def body(x, w):
            return jnp.tanh(x @ w), None

        def scanned(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        def unrolled(x, ws):
            for i in range(8):
                x, _ = body(x, ws[i])
            return x

        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
        fs = analyze_hlo(jax.jit(scanned).lower(x, ws).compile().as_text())
        fu = analyze_hlo(jax.jit(unrolled).lower(x, ws).compile().as_text())
        expect = 2 * 8 * 64 * 128 * 128
        assert fs["dot_flops"] == pytest.approx(expect, rel=0.05)
        assert fu["dot_flops"] == pytest.approx(expect, rel=0.05)

    def test_nested_scan_multipliers(self):
        def inner(x, w):
            return x @ w, None

        def outer(x, ws):
            def step(c, _):
                return jax.lax.scan(inner, c, ws)[0], None
            return jax.lax.scan(step, x, None, length=5)[0]

        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)
        a = analyze_hlo(jax.jit(outer).lower(x, ws).compile().as_text())
        assert a["dot_flops"] == pytest.approx(2 * 15 * 32**3, rel=0.05)

    def test_terms_and_bottleneck(self):
        analysis = dict(dot_flops=197e12, dot_bytes=0.0,
                        coll_bytes={"all-reduce": 100e9}, coll_count={},
                        dynamic_whiles=0, while_trips=[])
        t = roofline_terms(analysis)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["collective_s"] == pytest.approx(1.0)  # 2x AR / 4 links
        assert t["bottleneck"] in ("compute", "collective")
