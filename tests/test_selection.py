"""Bitset color-selection primitives vs python reference (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests
from hypothesis import given, settings, strategies as st

from repro.core import selection as sel


def py_first_zero(words):
    bits = []
    for w in words:
        for b in range(32):
            bits.append((int(w) >> b) & 1)
    for i, bit in enumerate(bits):
        if not bit:
            return i
    return len(bits) - 1


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=8))
def test_find_first_zero(words):
    w = jnp.asarray(np.array(words, dtype=np.uint32))
    got = int(sel.find_first_zero(w))
    assert got == py_first_zero(words)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 127), st.lists(st.integers(0, 2**32 - 1), min_size=4,
                                     max_size=4))
def test_set_bit(c, words):
    w = jnp.asarray(np.array(words, dtype=np.uint32))
    got = np.asarray(sel.set_bit(w, jnp.int32(c)))
    want = np.array(words, dtype=np.uint32)
    want[c // 32] |= np.uint32(1 << (c % 32))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 127), st.lists(st.integers(0, 2**32 - 1), min_size=4,
                                     max_size=4))
def test_mask_below(c, words):
    w = jnp.asarray(np.array(words, dtype=np.uint32))
    got = np.asarray(sel._mask_below(w, jnp.int32(c)))
    for bit in range(128):
        before = (int(words[bit // 32]) >> (bit % 32)) & 1
        after = (int(got[bit // 32]) >> (bit % 32)) & 1
        assert after == (1 if bit < c else before)


def test_staggered_wraps():
    # all colors below offset taken, above free -> picks first >= offset
    words = jnp.zeros((2,), jnp.uint32).at[0].set(jnp.uint32(0xFFFFFFFF))
    assert int(sel.staggered(words, jnp.int32(40))) == 40
    # everything >= offset taken -> wraps to global first fit
    words = jnp.asarray(np.array([0x1, 0xFFFFFFFF], np.uint32))
    assert int(sel.staggered(words, jnp.int32(32))) == 1


def test_top_bit_is_saturation_sentinel():
    """Color 32W-1 is reserved: a 32W-1 return always means "saturated".

    Boundary regression for the staggered ambiguity — previously a genuinely
    free last bit was indistinguishable from a full set, so ``staggered``
    wrapped below its offset while believing color 32W-1 was legal.
    """
    # only the (reserved) top bit free -> still reports saturation
    words = jnp.asarray(np.array([0xFFFFFFFF, 0x7FFFFFFF], np.uint32))
    assert int(sel.find_first_zero(words)) == 63
    assert int(sel.first_fit(words)) == 63
    # free = {5, 63}, offset 40: the reserved 63 is not legal, so staggered
    # wraps to 5 — and never hands out 63 while free colors remain
    words = jnp.asarray(np.array([0xFFFFFFFF ^ (1 << 5), 0x7FFFFFFF],
                                 np.uint32))
    assert int(sel.staggered(words, jnp.int32(40))) == 5
    # the last *legal* color (62) free at/above the offset: no wrap below
    words = jnp.asarray(np.array([0xFFFFFFFF ^ (1 << 5),
                                  0xFFFFFFFF ^ (1 << 30)], np.uint32))
    assert int(sel.staggered(words, jnp.int32(40))) == 62
    # fully saturated set: unambiguous sentinel
    words = jnp.asarray(np.array([0xFFFFFFFF, 0xFFFFFFFF], np.uint32))
    assert int(sel.find_first_zero(words)) == 63


def test_least_used_prefers_open_colors():
    usage = jnp.asarray(np.array([0, 5, 2, 0, 7] + [0] * 59, np.int32))
    words = jnp.zeros((2,), jnp.uint32).at[0].set(jnp.uint32(0b1))  # only c0 forbidden
    # among open colors {1,2,4}: usage 5,2,7 -> picks 2
    assert int(sel.least_used(words, usage)) == 2
    # if every open color is forbidden -> first fit
    words2 = jnp.asarray(np.array([0b10110111, 0], np.uint32))
    got = int(sel.least_used(words2, usage))
    assert got == sel.find_first_zero(words2)


def test_random_x_uniformity():
    """Random-X picks roughly uniformly among the X smallest free colors."""
    words = jnp.zeros((2,), jnp.uint32).at[0].set(jnp.uint32(0b1))
    key = jax.random.key(0)
    draws = []
    for i in range(600):
        r = jax.random.bits(jax.random.fold_in(key, i), (), jnp.uint32)
        draws.append(int(sel.random_x(words, 5, r)))
    vals, counts = np.unique(draws, return_counts=True)
    assert set(vals) == {1, 2, 3, 4, 5}
    assert counts.min() > 60  # ~120 each
