"""Pallas kernels vs pure-jnp oracle: shape/dtype sweeps + hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: property tests
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.firstfit import TILE_V


def _case(rng, v, d, mc):
    nbr = rng.integers(-2, mc + 8, (v, d)).astype(np.int32)
    active = rng.random(v) < 0.85
    rand = rng.integers(0, 2**32, v, dtype=np.uint32)
    return nbr, active, rand


@pytest.mark.parametrize("v", [1, 7, TILE_V, TILE_V + 3, 2 * TILE_V])
@pytest.mark.parametrize("d", [1, 16, 33])
@pytest.mark.parametrize("mc", [32, 64, 256])
def test_first_fit_sweep(rng, v, d, mc):
    nbr, active, rand = _case(rng, v, d, mc)
    got = ops.color_select(nbr, active, rand, max_colors=mc, x=0)
    want = ref.first_fit(jnp.asarray(nbr), jnp.asarray(active), mc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("x", [1, 5, 10, 50])
@pytest.mark.parametrize("mc", [64, 128])
def test_random_x_sweep(rng, x, mc):
    nbr, active, rand = _case(rng, 300, 21, mc)
    got = ops.color_select(nbr, active, rand, max_colors=mc, x=x)
    want = ref.random_x(jnp.asarray(nbr), jnp.asarray(active),
                        jnp.asarray(rand), x, mc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_random_x_within_free_set(rng):
    mc = 64
    nbr, active, rand = _case(rng, 128, 9, mc)
    got = np.asarray(ops.color_select(nbr, active, rand, max_colors=mc, x=5))
    occ = np.asarray(ref._forbidden(jnp.asarray(nbr), mc))
    for i in range(128):
        if active[i] and got[i] < mc - 1:
            assert not occ[i, got[i]], f"row {i} picked a forbidden color"


def test_conflict_sweep(rng):
    v, d, mc = 3 * TILE_V + 11, 17, 64
    nbr, active, rand = _case(rng, v, d, mc)
    myc = rng.integers(0, mc, v).astype(np.int32)
    myp = rng.integers(0, 10_000, v).astype(np.int32)
    nbrp = rng.integers(0, 10_000, (v, d)).astype(np.int32)
    got = ops.conflict(myc, myp, nbr, nbrp, active)
    want = ref.conflict(jnp.asarray(myc), jnp.asarray(myp), jnp.asarray(nbr),
                        jnp.asarray(nbrp), jnp.asarray(active))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(data=st.data(), v=st.integers(1, 80), d=st.integers(1, 12),
       mc_pow=st.integers(5, 8), x=st.sampled_from([0, 1, 5]))
def test_select_property(data, v, d, mc_pow, x):
    mc = 1 << mc_pow
    seed = data.draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    nbr = r.integers(-1, mc + 4, (v, d)).astype(np.int32)
    active = r.random(v) < 0.9
    rand = r.integers(0, 2**32, v, dtype=np.uint32)
    got = np.asarray(ops.color_select(nbr, active, rand, max_colors=mc, x=x))
    if x == 0:
        want = np.asarray(ref.first_fit(jnp.asarray(nbr),
                                        jnp.asarray(active), mc))
    else:
        want = np.asarray(ref.random_x(jnp.asarray(nbr), jnp.asarray(active),
                                       jnp.asarray(rand), x, mc))
    np.testing.assert_array_equal(got, want)
    # invariants: inactive rows 0; active rows never pick a neighbour color
    assert (got[~active] == 0).all()
    for i in np.nonzero(active)[0]:
        valid_nbrs = nbr[i][(nbr[i] > 0) & (nbr[i] < mc)]
        if got[i] < mc - 1:
            assert got[i] not in valid_nbrs
