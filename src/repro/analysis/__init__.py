"""repro-lint: SPMD-safety static analysis + jaxpr trace audit.

Two layers (DESIGN.md §9):

- the **AST rule engine** (``engine.run_lint``) — five rules grounded in
  this repo's shipped-and-fixed bug history: ``key-reuse`` (PR 4),
  ``id-overflow`` (PR 3), ``host-sync``, ``divergent-collective`` and
  ``nonuniform-loop`` (PR 6 / the SPMD uniformity invariant).
- the **trace audit** (``trace_audit.run_trace_audit``) — abstract-evals
  the public entry points at P=2 and asserts on the jaxpr itself:
  identical collective sequences across shards and schemes, zero host
  callbacks inside the fused loop bodies, one compile per PlanSignature.

CLI: ``python -m tools.repro_lint src`` (see tools/repro_lint.py).
"""
from .engine import (ANALYSIS_RULES, RULES, FileContext, LintResult,
                     lint_source, run_lint)
from .findings import (Finding, count_suppressions, load_baseline,
                       parse_suppressions, split_baselined, write_baseline)

__all__ = [
    "ANALYSIS_RULES", "RULES", "FileContext", "LintResult", "Finding",
    "lint_source", "run_lint", "count_suppressions", "parse_suppressions",
    "load_baseline", "split_baselined", "write_baseline",
]
