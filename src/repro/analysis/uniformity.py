"""AST-level shard-uniformity dataflow for SPMD python sources.

The SPMD invariant behind every collective in this repo (DESIGN.md §9):
all shards must execute the *same* sequence of collectives with the same
trip counts.  A value is **shard-uniform** when it is provably identical
on every shard; only uniform values may steer a ``lax.cond`` arm or loop
bound whose body communicates.

The abstract value lattice per name is :class:`Val`:

- ``static``  — a trace-time python value (config ints, tuples, shapes).
  Static implies uniform.
- ``uniform`` — a traced value identical across shards.  Sources:
  statics, collective *reductions* (``psum``/``pmax``/``pmin``/
  ``all_gather`` — their outputs are identical everywhere by
  construction), and the explicit :func:`repro.core.comm.shard_uniform`
  contract annotation.
- neither    — per-shard data.  Sources: ``axis_index``/``comm.index()``,
  ``ppermute`` outputs, and unannotated array parameters.

The analysis is flow-sensitive and intra-procedural with three
inter-procedural devices:

- module-level functions get a memoized **strict summary**: return
  uniformity computed with every parameter assumed per-shard.  A helper
  that launders its result through ``pmax``/``psum`` (e.g.
  ``recolor._needed_exchanges``) is therefore uniform at every call site.
- locally *resolvable* callables (nested ``def``s, lambdas, loop bodies
  handed to ``lax.while_loop``/``fori_loop``/``cond``/``switch``) are
  analyzed inline with the caller's environment; loop carries iterate to
  a fixpoint before reports are collected.
- ``comm.make_exchange(...)`` results are modeled as collective-bearing
  callables (the factory's closures ship ``ppermute``/``all_gather``).

Parameters seed from annotations: array-ish annotations (``ndarray``,
``Array``) are per-shard, any other annotation (``int``, ``tuple``,
config dataclasses) is static, and unannotated parameters are per-shard —
the conservative default that ``shard_uniform`` exists to override.

While executing, the analyzer records :class:`Report`s at every branch /
loop / host-sync site; the SPMD rules in ``rules_spmd.py`` turn reports
into findings.
"""
from __future__ import annotations

import ast
import builtins
import dataclasses
import re

# Collectives whose *execution* must be shard-uniform (a shard skipping one
# deadlocks or corrupts the exchange).  axis_index is excluded: reading the
# shard id in one branch cannot desynchronize anything.
COLLECTIVE_PRIMS = {"psum", "pmax", "pmin", "pmean", "all_gather",
                    "ppermute", "pshuffle", "all_to_all"}
# Collectives whose outputs are identical on every shard.
UNIFORM_PRIMS = {"psum", "pmax", "pmin", "pmean", "all_gather"}
# Primitives whose outputs are per-shard even from uniform inputs.
DIVERGENT_PRIMS = {"ppermute", "pshuffle", "all_to_all", "axis_index"}
# Known factories returning collective-bearing callables.
BEARING_FACTORIES = {"make_exchange"}
# Attributes that are static regardless of their base (shapes are trace-time
# constants under jit).
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}
# Builtins that preserve static-ness through plain python evaluation.
STATIC_BUILTINS = {"len", "range", "zip", "enumerate", "tuple", "list",
                   "set", "dict", "sorted", "reversed", "min", "max", "abs",
                   "sum", "int", "float", "bool", "str", "isinstance",
                   "getattr", "hasattr", "divmod", "round", "map", "filter",
                   "frozenset", "repr", "any", "all", "print", "type"}
ARRAYISH_ANN = re.compile(r"ndarray|Array|jnp\.|DeviceArray")
# Host-sync calls that force a device->host transfer when fed a traced value.
HOST_SYNC_CALLS = {"int", "float", "bool", "item", "asarray", "array",
                   "device_get", "block_until_ready", "tolist"}
HOST_SYNC_EXEMPT_FUNCS = {"stats_to_host"}   # the one blessed exit
_MAX_DEPTH = 25
# the lattice only descends (uniform/static bits can only turn off), so
# carry fixpoints converge in a couple of steps
_MAX_FIXPOINT = 4


@dataclasses.dataclass
class Val:
    """Abstract value: (uniform, static) bits + callable/tuple structure."""

    uniform: bool = False
    static: bool = False
    bearing: bool = False            # callable that executes collectives
    node: ast.AST | None = None      # FunctionDef/Lambda for callables
    env: dict | None = None          # closure environment (live reference)
    elems: list | None = None        # element Vals for tuples/lists

    def __post_init__(self):
        if self.static:
            self.uniform = True


def VS() -> Val:
    return Val(uniform=True, static=True)


def VU() -> Val:
    return Val(uniform=True, static=False)


def VN() -> Val:
    return Val(uniform=False, static=False)


def meet(*vals: Val) -> Val:
    """Pointwise AND of (uniform, static) — the result of combining values."""
    vals = [v if isinstance(v, Val) else VN() for v in vals]
    if not vals:
        return VS()
    return Val(uniform=all(v.uniform for v in vals),
               static=all(v.static for v in vals))


def join(a: Val, b: Val) -> Val:
    """Control-flow merge: a value is uniform only if both paths agree."""
    out = Val(uniform=a.uniform and b.uniform, static=a.static and b.static,
              bearing=a.bearing or b.bearing)
    if (a.elems is not None and b.elems is not None
            and len(a.elems) == len(b.elems)):
        out.elems = [join(x, y) for x, y in zip(a.elems, b.elems)]
    if a.node is not None and a.node is b.node:
        out.node, out.env = a.node, a.env
    return out


def same(a: Val, b: Val) -> bool:
    if (a.uniform, a.static) != (b.uniform, b.static):
        return False
    ae, be = a.elems or [], b.elems or []
    return len(ae) == len(be) and all(same(x, y) for x, y in zip(ae, be))


@dataclasses.dataclass
class Report:
    """One analyzed control-flow / host-sync site, for the SPMD rules."""

    kind: str          # "cond" | "switch" | "while" | "fori" | "if"
                       # | "pyloop" | "host-sync"
    line: int
    pred: Val          # predicate / trip-bound value at the site
    bearing: bool      # a collective executes under this site
    device: bool       # site sits in traced (device) code
    detail: str = ""


def _sig(v: Val) -> tuple:
    """Hashable abstract-value signature for the inline-call memo."""
    elems = tuple(_sig(e) for e in v.elems) if v.elems is not None else None
    return (v.uniform, v.static, v.bearing, id(v.node), elems)


def _func_name(func: ast.AST) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _recv_name(func: ast.AST) -> str:
    if isinstance(func, ast.Attribute):
        if isinstance(func.value, ast.Name):
            return func.value.id
        if isinstance(func.value, ast.Attribute):
            return func.value.attr
    return ""


def param_seed(arg: ast.arg) -> Val:
    """Seed a parameter from its annotation (see module docstring)."""
    if arg.annotation is not None:
        ann = ast.unparse(arg.annotation)
        return VN() if ARRAYISH_ANN.search(ann) else VS()
    return VN()


class ModuleAnalysis:
    """Whole-module driver: canonical pass + strict per-function summaries."""

    def __init__(self, tree: ast.Module, path: str = "<module>"):
        self.tree = tree
        self.path = path
        self.funcs: dict[str, ast.FunctionDef] = {}
        self.module_static: set[str] = set()
        self.reports: list[Report] = []
        self._strict: dict[str, Val] = {}
        self._strict_stack: set[str] = set()
        self._bearing_memo: dict[int, bool] = {}
        self._call_memo: dict[tuple, Val] = {}
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.funcs[node.name] = node
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for alias in node.names:
                    self.module_static.add(
                        (alias.asname or alias.name).split(".")[0])
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            self.module_static.add(n.id)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                    node.target, ast.Name):
                self.module_static.add(node.target.id)
            elif isinstance(node, ast.ClassDef):
                self.module_static.add(node.name)

    # -------------------------------------------------------------- passes --
    def run(self) -> list[Report]:
        """Canonical collecting pass over every module-level function."""
        for f in self.funcs.values():
            device = ((f.name.endswith("_spmd") or _is_jitted(f))
                      and f.name not in HOST_SYNC_EXEMPT_FUNCS)
            env = {a.arg: param_seed(a) for a in _all_args(f.args)}
            FuncAnalyzer(self, env, device=device, collect=True).exec_body(
                f.body)
        return self.reports

    def strict_summary(self, name: str) -> Val:
        """Return uniformity of ``name`` with all params per-shard (memoized;
        recursion breaks to per-shard)."""
        if name in self._strict:
            return self._strict[name]
        if name in self._strict_stack:
            return VN()
        f = self.funcs.get(name)
        if f is None:
            return VN()
        self._strict_stack.add(name)
        try:
            # arrays are per-shard; annotated scalars/configs keep their
            # static seeding (an `int` param is a trace-time constant
            # whoever the caller is)
            env = {a.arg: param_seed(a) for a in _all_args(f.args)}
            an = FuncAnalyzer(self, env, device=False, collect=False)
            an.exec_body(f.body)
            result = an.return_val()
        finally:
            self._strict_stack.discard(name)
        self._strict[name] = result
        return result

    # ------------------------------------------------------------- bearing --
    def is_bearing(self, node: ast.AST | None, env: dict | None = None,
                   _seen: set | None = None) -> bool:
        """Does calling/executing ``node`` run a collective primitive?"""
        if node is None:
            return False
        key = id(node)
        if key in self._bearing_memo:
            return self._bearing_memo[key]
        _seen = _seen or set()
        if key in _seen:
            return False
        _seen.add(key)
        found = False
        for n in ast.walk(node):
            if not isinstance(n, ast.Call):
                continue
            name = _func_name(n.func)
            if name in COLLECTIVE_PRIMS:
                found = True
                break
            if name in BEARING_FACTORIES:
                found = True
                break
            target = env.get(name) if env else None
            if isinstance(target, Val):
                if target.bearing:
                    found = True
                    break
                if target.node is not None and self.is_bearing(
                        target.node, target.env, _seen):
                    found = True
                    break
            elif name in self.funcs and self.is_bearing(
                    self.funcs[name], None, _seen):
                found = True
                break
        self._bearing_memo[key] = found
        return found


def _is_jitted(f: ast.FunctionDef) -> bool:
    for dec in f.decorator_list:
        if "jit" in ast.unparse(dec):
            return True
    return False


def _all_args(a: ast.arguments) -> list[ast.arg]:
    out = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    if a.vararg:
        out.append(a.vararg)
    if a.kwarg:
        out.append(a.kwarg)
    return out


class FuncAnalyzer:
    """Flow-sensitive abstract interpreter for one function body."""

    def __init__(self, mod: ModuleAnalysis, env: dict, device: bool,
                 collect: bool, depth: int = 0):
        self.mod = mod
        self.env = env
        self.device = device
        self.collect = collect
        self.depth = depth
        self.returns: list[Val] = []

    def report(self, kind: str, node: ast.AST, pred: Val, bearing: bool,
               detail: str = "") -> None:
        if self.collect:
            self.mod.reports.append(Report(
                kind=kind, line=getattr(node, "lineno", 0), pred=pred,
                bearing=bearing, device=self.device, detail=detail))

    def return_val(self) -> Val:
        if not self.returns:
            return VS()
        out = self.returns[0]
        for v in self.returns[1:]:
            out = join(out, v)
        return out

    # ----------------------------------------------------------- statements --
    def exec_body(self, stmts: list[ast.stmt]) -> None:
        for st in stmts:
            self.exec_stmt(st)

    def exec_stmt(self, st: ast.stmt) -> None:
        if isinstance(st, ast.Assign):
            v = self.eval(st.value)
            for t in st.targets:
                self.assign(t, v)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self.assign(st.target, self.eval(st.value))
        elif isinstance(st, ast.AugAssign):
            v = meet(self.eval(st.target), self.eval(st.value))
            self.assign(st.target, v)
        elif isinstance(st, ast.Return):
            self.returns.append(
                self.eval(st.value) if st.value is not None else VS())
        elif isinstance(st, ast.If):
            self.exec_if(st)
        elif isinstance(st, (ast.For, ast.While)):
            self.exec_pyloop(st)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.env[st.name] = Val(
                uniform=False, static=False, node=st, env=self.env,
                bearing=self.mod.is_bearing(st, self.env))
        elif isinstance(st, ast.Expr):
            self.eval(st.value)
        elif isinstance(st, ast.With):
            for item in st.items:
                v = self.eval(item.context_expr)
                if item.optional_vars is not None:
                    self.assign(item.optional_vars, v)
            self.exec_body(st.body)
        elif isinstance(st, ast.Try):
            self.exec_body(st.body)
            for h in st.handlers:
                self.exec_body(h.body)
            self.exec_body(st.orelse)
            self.exec_body(st.finalbody)
        elif isinstance(st, (ast.Assert, ast.Raise, ast.Delete)):
            for n in ast.iter_child_nodes(st):
                if isinstance(n, ast.expr):
                    self.eval(n)
        # Pass / Import / Global / Break / Continue: nothing to track

    def assign(self, target: ast.expr, v: Val) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = v
        elif isinstance(target, (ast.Tuple, ast.List)):
            elems = v.elems
            if elems is None or len(elems) != len(target.elts):
                elems = [Val(uniform=v.uniform, static=v.static)
                         for _ in target.elts]
            for t, e in zip(target.elts, elems):
                if isinstance(t, ast.Starred):
                    self.assign(t.value, Val(uniform=v.uniform,
                                             static=v.static))
                else:
                    self.assign(t, e)
        elif isinstance(target, (ast.Subscript, ast.Attribute)):
            base = target.value
            while isinstance(base, (ast.Subscript, ast.Attribute)):
                base = base.value
            if isinstance(base, ast.Name) and base.id in self.env:
                self.env[base.id] = join(self.env[base.id], v)

    def exec_if(self, st: ast.If) -> None:
        test = self.eval(st.test)
        bearing = any(self.mod.is_bearing(s, self.env)
                      for s in st.body + st.orelse)
        self.report("if", st, test, bearing)
        before = dict(self.env)
        self.env = dict(before)
        self.exec_body(st.body)
        s1 = self.env
        self.env = dict(before)
        self.exec_body(st.orelse)
        s2 = self.env
        merged = dict(before)
        for name in set(s1) | set(s2):
            a = s1.get(name, before.get(name, VN()))
            b = s2.get(name, before.get(name, VN()))
            merged[name] = join(a, b)
        self.env = merged

    def exec_pyloop(self, st: ast.For | ast.While) -> None:
        if isinstance(st, ast.For):
            it = self.eval(st.iter)
            self.assign(st.target, Val(uniform=it.uniform, static=it.static))
            bound = it
        else:
            bound = self.eval(st.test)
        bearing = any(self.mod.is_bearing(s, self.env) for s in st.body)
        self.report("pyloop", st, bound, bearing)
        # two merge passes approximate the loop fixpoint
        for _ in range(2):
            before = dict(self.env)
            self.exec_body(st.body)
            for name, v in list(self.env.items()):
                if name in before:
                    self.env[name] = join(before[name], v)
        self.exec_body(st.orelse)

    # ---------------------------------------------------------- expressions --
    def eval(self, node: ast.expr | None) -> Val:
        if node is None:
            return VS()
        if isinstance(node, ast.Constant):
            return VS()
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            if node.id in self.mod.funcs:
                f = self.mod.funcs[node.id]
                return Val(node=f, env=None,
                           bearing=self.mod.is_bearing(f))
            if (node.id in self.mod.module_static
                    or node.id in STATIC_BUILTINS
                    or hasattr(builtins, node.id)):
                return VS()
            return VN()
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return VS()
            base = self.eval(node.value)
            return Val(uniform=base.uniform, static=base.static,
                       bearing=base.bearing)
        if isinstance(node, ast.Subscript):
            base = self.eval(node.value)
            idx = self.eval(node.slice)
            if (base.elems is not None and isinstance(node.slice, ast.Constant)
                    and isinstance(node.slice.value, int)
                    and -len(base.elems) <= node.slice.value
                    < len(base.elems)):
                return base.elems[node.slice.value]
            return meet(base, idx)
        if isinstance(node, (ast.Tuple, ast.List)):
            elems = [self.eval(e) for e in node.elts]
            v = meet(*elems) if elems else VS()
            return Val(uniform=v.uniform, static=v.static, elems=elems)
        if isinstance(node, ast.Dict):
            vals = ([self.eval(k) for k in node.keys if k is not None]
                    + [self.eval(v) for v in node.values])
            return meet(*vals) if vals else VS()
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` is a python-static property:
            # tracers are never None, so the branch is resolved at trace time.
            if (len(node.ops) == 1 and isinstance(node.ops[0],
                                                  (ast.Is, ast.IsNot))
                    and any(isinstance(s, ast.Constant) and s.value is None
                            for s in (node.left, node.comparators[0]))):
                self.eval(node.left)
                self.eval(node.comparators[0])
                return VS()
            return meet(self.eval(node.left),
                        *[self.eval(c) for c in node.comparators])
        if isinstance(node, ast.BoolOp):
            return meet(*[self.eval(v) for v in node.values])
        if isinstance(node, ast.BinOp):
            return meet(self.eval(node.left), self.eval(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, ast.IfExp):
            return meet(self.eval(node.test),
                        join(self.eval(node.body), self.eval(node.orelse)))
        if isinstance(node, ast.Lambda):
            return Val(node=node, env=self.env,
                       bearing=self.mod.is_bearing(node, self.env))
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            return self.eval_comp(node)
        if isinstance(node, ast.Call):
            return self.eval_call(node)
        if isinstance(node, ast.JoinedStr):
            return meet(*[self.eval(v.value) for v in node.values
                          if isinstance(v, ast.FormattedValue)] or [VS()])
        if isinstance(node, ast.Slice):
            return meet(self.eval(node.lower), self.eval(node.upper),
                        self.eval(node.step))
        return VN()

    def eval_comp(self, node) -> Val:
        env0 = dict(self.env)
        parts = []
        for gen in node.generators:
            it = self.eval(gen.iter)
            parts.append(it)
            self.assign(gen.target, Val(uniform=it.uniform, static=it.static))
            parts.extend(self.eval(c) for c in gen.ifs)
        if isinstance(node, ast.DictComp):
            elt = meet(self.eval(node.key), self.eval(node.value))
        else:
            elt = self.eval(node.elt)
        # a comprehension of lambdas is a branch table: propagate bearing
        bearing = (isinstance(getattr(node, "elt", None), ast.Lambda)
                   and self.mod.is_bearing(node.elt, self.env))
        self.env = env0
        v = meet(elt, *parts)
        return Val(uniform=v.uniform, static=v.static, bearing=bearing)

    # ---------------------------------------------------------------- calls --
    def eval_call(self, node: ast.Call) -> Val:
        name = _func_name(node.func)
        recv = _recv_name(node.func)

        if name in ("cond",) and recv in ("lax", "jax"):
            return self.eval_lax_cond(node)
        if name == "switch" and recv in ("lax", "jax"):
            return self.eval_lax_switch(node)
        if name == "while_loop" and recv in ("lax", "jax"):
            return self.eval_lax_while(node)
        if name == "fori_loop" and recv in ("lax", "jax"):
            return self.eval_lax_fori(node)
        if name == "scan" and recv in ("lax", "jax"):
            return self.eval_lax_scan(node)

        arg_vals = [self.eval(a) for a in node.args]
        kw_vals = [self.eval(k.value) for k in node.keywords]

        if name == "shard_uniform":
            a = arg_vals[0] if arg_vals else VS()
            return Val(uniform=True, static=a.static)
        if name in UNIFORM_PRIMS:
            return VU()
        if name in DIVERGENT_PRIMS:
            return VN()
        if name == "index" and recv == "comm":          # comm.index()
            return VN()
        if name in BEARING_FACTORIES:
            return Val(bearing=True)
        if self.device and name in HOST_SYNC_CALLS:
            self.check_host_sync(node, name, arg_vals)

        # resolvable local callable -> inline analysis
        target = None
        if isinstance(node.func, ast.Name):
            target = self.env.get(node.func.id)
        if isinstance(target, Val) and target.node is not None:
            return self.call_callable(target, arg_vals, node)
        # module-level function -> strict summary
        if isinstance(node.func, ast.Name) and node.func.id in self.mod.funcs:
            return self.mod.strict_summary(node.func.id)

        base = self.eval(node.func) if isinstance(node.func,
                                                  ast.Attribute) else VS()
        v = meet(base, *(arg_vals + kw_vals))
        if name in STATIC_BUILTINS and isinstance(node.func, ast.Name):
            if name == "len":
                return VS()      # sizes are trace-time constants under jit
            return v
        # any other call on static inputs yields a traced (uniform) value
        return Val(uniform=v.uniform, static=False)

    def check_host_sync(self, node: ast.Call, name: str, arg_vals) -> None:
        if name in ("int", "float", "bool") and not isinstance(node.func,
                                                               ast.Name):
            return
        if name in ("item", "tolist", "block_until_ready"):
            if not isinstance(node.func, ast.Attribute):
                return
            arg_vals = [self.eval(node.func.value)]
        if name in ("asarray", "array"):
            # only numpy's asarray/array forces a host transfer
            if _recv_name(node.func) not in ("np", "numpy", "onp"):
                return
        if name == "device_get" and _recv_name(node.func) not in (
                "jax", "api"):
            return
        if all(v.static for v in arg_vals):
            return               # int(x.shape[0]) etc: trace-time constants
        self.report("host-sync", node, meet(*arg_vals) if arg_vals else VS(),
                    bearing=False, detail=name)

    def call_callable(self, target: Val, arg_vals: list[Val],
                      site: ast.Call | None) -> Val:
        if self.depth >= _MAX_DEPTH:
            return VN()
        memo_key = None
        if site is None or not site.keywords:
            memo_key = (id(target.node), id(target.env), self.device,
                        tuple(_sig(v) for v in arg_vals))
            hit = self.mod._call_memo.get(memo_key)
            if hit is not None:
                return hit
        fn = target.node
        env = dict(target.env) if target.env is not None else {}
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            args = fn.args
            params = list(args.posonlyargs) + list(args.args)
            inner = FuncAnalyzer(self.mod, env, device=self.device,
                                 collect=False, depth=self.depth + 1)
            # bind positional args, then defaults for the rest
            defaults = list(args.defaults)
            n_no_default = len(params) - len(defaults)
            for i, p in enumerate(params):
                if i < len(arg_vals):
                    env[p.arg] = arg_vals[i]
                elif i >= n_no_default:
                    env[p.arg] = inner.eval(defaults[i - n_no_default])
                else:
                    env[p.arg] = param_seed(p)
            # bind keyword args from the call site
            if site is not None:
                by_name = {p.arg: p for p in params}
                for kw in site.keywords:
                    if kw.arg in by_name:
                        env[kw.arg] = self.eval(kw.value)
            if isinstance(fn, ast.Lambda):
                out = inner.eval(fn.body)
            else:
                inner.exec_body(fn.body)
                out = inner.return_val()
            if memo_key is not None:
                self.mod._call_memo[memo_key] = out
            return out
        return VN()

    def resolve_callable(self, expr: ast.expr) -> Val:
        v = self.eval(expr)
        if v.node is None and isinstance(expr, ast.Name):
            f = self.mod.funcs.get(expr.id)
            if f is not None:
                return Val(node=f, env=None, bearing=self.mod.is_bearing(f))
        return v

    def _branch_call(self, branch: Val, arg_vals: list[Val]) -> Val:
        if branch.node is not None:
            # device: lax-traced bodies are device code by definition
            prev, self.device = self.device, True
            try:
                return self.call_callable(branch, arg_vals, None)
            finally:
                self.device = prev
        return VN()

    def _traced_child(self, target: Val, arg_vals: list[Val],
                      collect: bool) -> "FuncAnalyzer | None":
        """Analyze a lax-traced callable with explicit arg seeds, returning
        the child analyzer (device=True).  None if unresolvable."""
        fn = target.node
        if fn is None:
            return None
        env = dict(target.env) if target.env is not None else {}
        args = fn.args
        params = list(args.posonlyargs) + list(args.args)
        inner = FuncAnalyzer(self.mod, env, device=True,
                             collect=collect, depth=self.depth + 1)
        for i, p in enumerate(params):
            env[p.arg] = arg_vals[i] if i < len(arg_vals) else VN()
        if isinstance(fn, ast.Lambda):
            inner.returns.append(inner.eval(fn.body))
        else:
            inner.exec_body(fn.body)
        return inner

    def eval_lax_cond(self, node: ast.Call) -> Val:
        if not node.args:
            return VN()
        pred = self.eval(node.args[0])
        branches = [self.resolve_callable(b) for b in node.args[1:3]]
        operands = [self.eval(a) for a in node.args[3:]]
        bearing = any(b.bearing or self.mod.is_bearing(b.node, b.env)
                      for b in branches)
        self.report("cond", node, pred, bearing)
        results = [self._branch_call(b, operands) for b in branches
                   if b.node is not None]
        if len(results) == len(branches) and results:
            out = results[0]
            for r in results[1:]:
                out = join(out, r)
            return meet_structured(pred, out)
        return meet(pred, *operands)

    def eval_lax_switch(self, node: ast.Call) -> Val:
        if len(node.args) < 2:
            return VN()
        pred = self.eval(node.args[0])
        table = self.eval(node.args[1])
        bearing = table.bearing
        if isinstance(node.args[1], (ast.List, ast.Tuple)):
            resolved = [self.resolve_callable(e) for e in node.args[1].elts]
            bearing = bearing or any(
                b.bearing or self.mod.is_bearing(b.node, b.env)
                for b in resolved)
        self.report("switch", node, pred, bearing)
        operands = [self.eval(a) for a in node.args[2:]]
        return meet(pred, *operands)

    def eval_lax_while(self, node: ast.Call) -> Val:
        if len(node.args) < 3:
            return VN()
        cond_fn = self.resolve_callable(node.args[0])
        body_fn = self.resolve_callable(node.args[1])
        carry = self.eval(node.args[2])
        bearing = body_fn.bearing or self.mod.is_bearing(body_fn.node,
                                                         body_fn.env)
        carry = self._carry_fixpoint(body_fn, carry, index=None)
        cond_child = self._traced_child(cond_fn, [carry], collect=False)
        cond_v = cond_child.return_val() if cond_child is not None else VN()
        self.report("while", node, cond_v, bearing)
        if self.collect:   # one collecting pass at the fixpoint
            self._traced_child(body_fn, [carry], collect=True)
            self._traced_child(cond_fn, [carry], collect=True)
        return carry

    def eval_lax_fori(self, node: ast.Call) -> Val:
        if len(node.args) < 4:
            return VN()
        lo, hi = self.eval(node.args[0]), self.eval(node.args[1])
        body_fn = self.resolve_callable(node.args[2])
        carry = self.eval(node.args[3])
        bearing = body_fn.bearing or self.mod.is_bearing(body_fn.node,
                                                         body_fn.env)
        bound = meet(lo, hi)
        self.report("fori", node, bound, bearing)
        carry = self._carry_fixpoint(body_fn, carry,
                                     index=Val(uniform=bound.uniform))
        if self.collect:
            self._traced_child(body_fn, [Val(uniform=bound.uniform), carry],
                               collect=True)
        return carry

    def eval_lax_scan(self, node: ast.Call) -> Val:
        args = [self.eval(a) for a in node.args]
        if len(node.args) >= 2:
            body_fn = self.resolve_callable(node.args[0])
            bearing = body_fn.bearing or self.mod.is_bearing(
                body_fn.node, body_fn.env)
            # scan's trip count is the xs length — static — so only flag
            # nothing here; carries still degrade through the fixpoint.
            carry = args[1] if len(args) > 1 else VN()
            xs = VN()
            out = self._carry_fixpoint(body_fn, carry, index=xs, scan=True)
            if self.collect:
                self._traced_child(body_fn, [out, xs], collect=True)
            return out
        return meet(*args) if args else VN()

    def _carry_fixpoint(self, body_fn: Val, carry: Val, index: Val | None,
                        scan: bool = False) -> Val:
        if body_fn.node is None:
            return VN()
        for _ in range(_MAX_FIXPOINT):
            call_args = [carry] if index is None else [index, carry]
            if scan:
                call_args = [carry, index]
            child = self._traced_child(body_fn, call_args, collect=False)
            if child is None:
                return VN()
            ret = child.return_val()
            if scan and ret.elems:
                ret = ret.elems[0]
            new = join(carry, ret)
            if same(new, carry):
                return new
            carry = new
        return carry


def meet_structured(guard: Val, v: Val) -> Val:
    """meet() that degrades tuple elements by a guard without flattening."""
    if v.elems is None:
        return meet(guard, v)
    return Val(uniform=guard.uniform and v.uniform,
               static=guard.static and v.static,
               elems=[meet_structured(guard, e) for e in v.elems])


def analyze_module(source: str, path: str = "<module>") -> ModuleAnalysis:
    """Parse + run the canonical collecting pass; returns the analysis."""
    tree = ast.parse(source, filename=path)
    mod = ModuleAnalysis(tree, path)
    mod.run()
    return mod
