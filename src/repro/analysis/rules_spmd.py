"""The three SPMD rules driven by the uniformity analysis (uniformity.py):

- **host-sync** — ``int()``/``.item()``/``np.asarray`` on a traced value
  inside device code under ``core/``/``kernels/``.  Each one is a silent
  device->host round trip inside what should be a fused program;
  ``comm.stats_to_host`` is the one blessed exit.  Static arguments
  (``int(x.shape[0])``) never fire.
- **divergent-collective** — a collective (or collective-bearing closure,
  e.g. a ``make_exchange`` product) under a ``lax.cond``/``lax.switch``
  arm whose predicate is not provably shard-uniform, or under a
  non-static python branch.  A shard that skips a ``ppermute`` round its
  peer executes deadlocks the exchange (or silently corrupts it under
  vmap simulation) — cf. Gebremedhin-style superstep schemes where every
  round is globally agreed.
- **nonuniform-loop** — a python loop over a non-static bound inside
  device code (unrolls per-trace, defeating the PlanSignature program
  cache — PR 6's bug class), or a ``lax.while_loop``/``fori_loop`` whose
  body communicates but whose trip condition is not shard-uniform.

All three consume the :class:`~repro.analysis.uniformity.Report` stream;
the engine runs the analysis once per file and hands it to each rule.
"""
from __future__ import annotations

import re

from .findings import Finding

HOT_PATH = re.compile(r"(^|/)(core|kernels)/")


def _hot(ctx) -> bool:
    return bool(HOT_PATH.search(ctx.path.replace("\\", "/")))


def check_host_sync(ctx) -> list[Finding]:
    if not _hot(ctx) or ctx.analysis is None:
        return []
    out = []
    for r in ctx.analysis.reports:
        if r.kind != "host-sync" or not r.device:
            continue
        out.append(Finding(
            ctx.path, r.line, "host-sync",
            f"host sync '{r.detail}(...)' on a traced value inside device "
            f"code (blessed exit: comm.stats_to_host)"))
    return out


def check_divergent_collective(ctx) -> list[Finding]:
    if ctx.analysis is None:
        return []
    out = []
    for r in ctx.analysis.reports:
        if not r.bearing:
            continue
        if r.kind in ("cond", "switch") and not r.pred.uniform:
            out.append(Finding(
                ctx.path, r.line, "divergent-collective",
                f"collective under lax.{r.kind} whose predicate is not "
                f"provably shard-uniform (derive it from a pmax/psum "
                f"reduction or assert the contract via comm.shard_uniform)"))
        elif r.kind == "if" and not r.pred.static:
            out.append(Finding(
                ctx.path, r.line, "divergent-collective",
                f"collective under a python branch on a non-static value "
                f"(shards may disagree; hoist the collective or make the "
                f"branch static)"))
    return out


def check_nonuniform_loop(ctx) -> list[Finding]:
    if ctx.analysis is None:
        return []
    out = []
    for r in ctx.analysis.reports:
        if r.kind == "pyloop" and r.device and not r.pred.static:
            out.append(Finding(
                ctx.path, r.line, "nonuniform-loop",
                f"python loop over a non-static bound in device code "
                f"(unrolls per trace and defeats the PlanSignature program "
                f"cache; use lax.fori_loop/while_loop)"))
        elif r.kind in ("while", "fori") and r.bearing and not r.pred.uniform:
            what = ("trip condition" if r.kind == "while"
                    else "trip bound")
            out.append(Finding(
                ctx.path, r.line, "nonuniform-loop",
                f"lax.{r.kind}_loop body communicates but its {what} is not "
                f"provably shard-uniform (pmax-reduce the bound so every "
                f"shard runs the same number of collectives)"))
    return out
