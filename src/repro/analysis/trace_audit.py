"""Layer 2 of repro-lint: the jaxpr collective audit (DESIGN.md §9).

Where the AST rules reason about *source*, this layer abstract-evals the
shipped entry points at P=2 and asserts on the traced program itself:

1. **Shard-uniform collective sequence** — the ordered list of collective
   primitives (psum/pmax/ppermute/all_gather/...) in the per-shard
   program must be exactly the list in the vmapped (``run_sim``) and
   graph-batched (``color_many`` inner) programs, for every exchange
   scheme.  A shard- or lane-dependent collective would show up as a
   sequence mismatch — the static moral equivalent of a deadlock.
2. **Scheme resolution** — ``scheme="auto"`` must trace to bitwise the
   program of whichever concrete scheme ``resolve_scheme`` picks: same
   collective sequence, nothing else.
3. **No host callbacks** — the fused pipeline jaxprs (including every
   ``while``/``cond``/``scan`` sub-jaxpr) contain zero callback
   primitives; the device loop never bounces through the host.
4. **One compile per PlanSignature** — dispatching a ≥3-signature graph
   family through ``pipeline_sim`` twice traces exactly once per
   distinct signature (the program-cache contract of DESIGN.md §2).

``run_trace_audit`` returns a :class:`TraceAudit`; the
``tools.repro_lint --trace-audit`` CLI and ``tests/test_trace_audit.py``
both consume it.
"""
from __future__ import annotations

import dataclasses

#: collective primitive names we pin sequences of (jaxpr ``eqn.primitive``).
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmax", "pmin", "pmean", "all_gather", "ppermute", "pshuffle",
    "all_to_all", "axis_index",
})

#: host-callback primitives that must never appear in a fused program.
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call",
})


@dataclasses.dataclass
class TraceAudit:
    """Outcome of one audit run: passed checks + human-readable failures."""

    checks: list = dataclasses.field(default_factory=list)    # (name, detail)
    failures: list = dataclasses.field(default_factory=list)  # str

    @property
    def ok(self) -> bool:
        return not self.failures

    def record(self, name: str, ok: bool, detail: str) -> None:
        if ok:
            self.checks.append((name, detail))
        else:
            self.failures.append(f"{name}: {detail}")

    def summary_lines(self) -> list:
        lines = [f"trace-audit: {len(self.checks)} check(s) passed, "
                 f"{len(self.failures)} failure(s)"]
        lines += [f"  ok   {name}: {detail}" for name, detail in self.checks]
        lines += [f"  FAIL {msg}" for msg in self.failures]
        return lines


# ------------------------------------------------------- jaxpr traversal --

def _param_jaxprs(params):
    """Sub-jaxprs referenced by one equation, in params order.

    Covers ``cond`` (branches), ``while`` (cond/body), ``scan``/``pjit``/
    ``remat``/``custom_*`` (jaxpr) without enumerating primitive names:
    anything shaped like a (Closed)Jaxpr in the params is walked.
    """
    for v in params.values():
        items = v if isinstance(v, (list, tuple)) else [v]
        for item in items:
            inner = getattr(item, "jaxpr", item)
            if hasattr(inner, "eqns"):
                yield inner


def _walk_prims(jaxpr, out: list) -> None:
    for eqn in jaxpr.eqns:
        out.append(eqn.primitive.name)
        for sub in _param_jaxprs(eqn.params):
            _walk_prims(sub, out)


def prim_sequence(closed_jaxpr) -> tuple:
    """Every primitive in program order, sub-jaxprs inlined at their eqn."""
    out: list = []
    _walk_prims(getattr(closed_jaxpr, "jaxpr", closed_jaxpr), out)
    return tuple(out)


def collective_sequence(closed_jaxpr) -> tuple:
    return tuple(p for p in prim_sequence(closed_jaxpr)
                 if p in COLLECTIVE_PRIMS)


def callback_prims(closed_jaxpr) -> tuple:
    return tuple(p for p in prim_sequence(closed_jaxpr)
                 if p in CALLBACK_PRIMS)


# ------------------------------------------------------------- the audit --

def _shard_aval(v, jax):
    """Per-shard ShapeDtypeStruct: drop the leading P axis of a stacked
    partition array."""
    import numpy as np
    v = np.asarray(v)
    return jax.ShapeDtypeStruct(v.shape[1:], v.dtype)


def _entry_jaxprs(pg, cfg, P, jax):
    """(name -> abstract jaxpr) for one resolved config.

    ``pipe`` / ``loop`` are the per-shard SPMD programs behind
    ``pipeline_sim`` / ``recolor_loop_sim``; ``pipe_vmap`` is the
    ``run_sim`` lane-stacked program and ``many`` the graph-batched
    ``color_many`` inner program — the sequence equality between them is
    check (1).
    """
    import numpy as np

    from ..core.comm import AXIS, run_sim
    from ..core.pipeline import (_plan_static, color_then_recolor,
                                 recolor_loop_spmd)

    arrs = pg.arrays(sparse=cfg.needs_sparse_plan)
    ps = _plan_static(pg, cfg)
    shard_arrs = {k: _shard_aval(v, jax) for k, v in arrs.items()}
    n_local_max = shard_arrs["indptr"].shape[0] - 1
    n_slots = shard_arrs["prio"].shape[0]   # n_local_max + max_ghost + 1
    order = jax.ShapeDtypeStruct((n_local_max,), np.int32)
    view = jax.ShapeDtypeStruct((n_slots,), np.int32)
    key = jax.random.key(0)
    axis_env = [(AXIS, P)]

    pipe = lambda a, o, ck, rk: color_then_recolor(
        a, o, ck, rk, cfg=cfg, P_size=P, plan_static=ps)
    loop = lambda a, v, rk: recolor_loop_spmd(
        a, v, rk, cfg=cfg, P_size=P, plan_static=ps)

    stack = lambda s: jax.ShapeDtypeStruct((P,) + tuple(s.shape), s.dtype)
    full_arrs = {k: stack(v) for k, v in shard_arrs.items()}
    pipe_vmap = lambda a, o, ck, rk: run_sim(pipe, P, (a, o), (ck, rk))
    many = jax.vmap(pipe_vmap, in_axes=(0, 0, 0, 0))
    batch = lambda s: jax.ShapeDtypeStruct((2,) + tuple(s.shape), s.dtype)
    keys2 = jax.numpy.stack([key, jax.random.key(1)])

    return {
        "pipe": jax.make_jaxpr(pipe, axis_env=axis_env)(
            shard_arrs, order, key, key),
        "loop": jax.make_jaxpr(loop, axis_env=axis_env)(
            shard_arrs, view, key),
        "pipe_vmap": jax.make_jaxpr(pipe_vmap)(
            full_arrs, stack(order), key, key),
        "many": jax.make_jaxpr(many)(
            {k: batch(v) for k, v in full_arrs.items()},
            batch(stack(order)), keys2, keys2),
    }


def _audit_collectives(audit: TraceAudit, pg, base_cfg, P, jax) -> None:
    import dataclasses as dc

    from ..core.comm import ALLGATHER, AUTO, SPARSE, resolve_scheme
    from ..core.pipeline import resolve_pipeline_cfg

    def with_scheme(scheme):
        cfg = dc.replace(
            base_cfg, color=dc.replace(base_cfg.color, scheme=scheme),
            recolor=dc.replace(base_cfg.recolor, scheme=scheme))
        return resolve_pipeline_cfg(pg, cfg)

    seqs = {}     # scheme -> entry name -> collective sequence
    for scheme in (SPARSE, ALLGATHER, AUTO):
        jaxprs = _entry_jaxprs(pg, with_scheme(scheme), P, jax)
        seqs[scheme] = {n: collective_sequence(j) for n, j in jaxprs.items()}
        for name, j in jaxprs.items():
            cbs = callback_prims(j)
            detail = (f"{name}/{scheme}: callback-free fused program"
                      if not cbs else f"{name}/{scheme}: {list(cbs)}")
            audit.record("no-host-callbacks", not cbs, detail)

    # Under run_sim's lane-vmap, shuffles (ppermute/all_gather/axis_index)
    # lower into lane gathers; cross-shard *reductions* keep their
    # primitive.  So the shard-uniformity pin is: the ordered reduction
    # subsequence survives batching bit-for-bit, and adding the graph
    # batch axis (color_many) changes nothing at all.
    reductions = {"psum", "pmax", "pmin", "pmean"}
    red = lambda seq: tuple(p for p in seq if p in reductions)
    for scheme in (SPARSE, ALLGATHER):
        per_shard = seqs[scheme]["pipe"]
        audit.record(
            "collectives-present", len(per_shard) > 0,
            f"pipe/{scheme}: {len(per_shard)} collective(s) in the "
            f"per-shard program")
        same = red(seqs[scheme]["pipe_vmap"]) == red(per_shard)
        audit.record(
            "shard-uniform-sequence", same,
            f"pipe_vmap/{scheme} reduction sequence "
            + (f"matches per-shard program ({len(red(per_shard))} "
               f"reduction(s))" if same else
               f"diverges: {red(seqs[scheme]['pipe_vmap'])[:8]} vs "
               f"{red(per_shard)[:8]}"))
        same = seqs[scheme]["many"] == seqs[scheme]["pipe_vmap"]
        audit.record(
            "batch-invariant-sequence", same,
            f"many/{scheme} collective sequence "
            + ("identical to the single-graph lane program" if same else
               f"diverges: {seqs[scheme]['many'][:8]} vs "
               f"{seqs[scheme]['pipe_vmap'][:8]}"))

    resolved = resolve_scheme(AUTO, pg)
    for name in ("pipe", "loop", "pipe_vmap", "many"):
        same = seqs[AUTO][name] == seqs[resolved][name]
        audit.record(
            "auto-resolves-identically", same,
            f"{name}: auto == {resolved}"
            + ("" if same else
               f" FAILED ({seqs[AUTO][name][:8]} vs "
               f"{seqs[resolved][name][:8]})"))

    # recolor-only loop is a strict suffix family of the full pipeline's
    # collectives: the loop must not invent exchanges the pipeline lacks.
    for scheme in (SPARSE, ALLGATHER):
        loop_set = set(seqs[scheme]["loop"])
        pipe_set = set(seqs[scheme]["pipe"])
        audit.record(
            "loop-within-pipe", loop_set <= pipe_set,
            f"loop/{scheme} collective kinds {sorted(loop_set)} within "
            f"pipe's {sorted(pipe_set)}")


def _audit_compile_cache(audit: TraceAudit, graphs, cfg, P, jax) -> None:
    """One XLA trace per distinct PlanSignature across a graph family."""
    from ..core.graph import partition_graph
    from ..core.ordering import NATURAL, compute_order
    from ..core.pipeline import (pipeline_sim, plan_signature,
                                 program_cache_clear, program_cache_stats)

    program_cache_clear()
    sigs = set()
    dispatches = 0
    for g in graphs:
        pg = partition_graph(g, P)
        sigs.add(plan_signature(pg, cfg))
        order = compute_order(pg, NATURAL)
        for seed in (0, 1):
            key = jax.random.key(seed)
            pipeline_sim(pg, order, cfg, recolor_key=key)
            dispatches += 1
    stats = program_cache_stats()
    audit.record(
        "distinct-signatures", len(sigs) >= 3,
        f"{len(sigs)} distinct PlanSignature(s) in the swept family")
    audit.record(
        "one-compile-per-signature", stats["traces"] == len(sigs),
        f"{dispatches} dispatches -> {stats['traces']} trace(s) for "
        f"{len(sigs)} signature(s) (hits={stats['hits']}, "
        f"misses={stats['misses']})")


def run_trace_audit(P: int = 2) -> TraceAudit:
    """Run the full audit on tiny P=2 graphs (a few seconds of compiles)."""
    import jax

    from ..core.graph import partition_graph
    from ..core.pipeline import PipelineConfig
    from ..core.recolor import RecolorConfig
    from ..core.rmat import grid2d, rmat_good
    from ..core.speculative import ColorConfig

    audit = TraceAudit()
    base_cfg = PipelineConfig(
        color=ColorConfig(max_colors=64, superstep=16, max_rounds=8),
        recolor=RecolorConfig(max_colors=64, chunk=32),
        n_iters=2, patience=0)

    g = grid2d(8, 8, 9)
    pg = partition_graph(g, P)
    _audit_collectives(audit, pg, base_cfg, P, jax)

    # ≥3 signatures: two grid sizes (different n_local_max) + an rmat
    # (different degree structure); each dispatched twice.
    family = [grid2d(8, 8, 9), grid2d(16, 16, 9), rmat_good(6, 4, seed=1)]
    _audit_compile_cache(audit, family, base_cfg, P, jax)
    return audit
