"""Findings, inline suppressions and the committed baseline.

A :class:`Finding` is one rule hit at one source location.  Two escape
hatches keep the linter adoptable without blocking on a full cleanup:

- **inline suppressions** — a ``# repro-lint: disable=<rule>[,<rule>...]``
  comment on the offending line silences those rules for that line only.
  The tier-1 self-check asserts ``src/repro/core`` and ``src/repro/kernels``
  carry *zero* of these (DESIGN.md §9): hot-path code must satisfy the
  rules outright (via real fixes or ``comm.shard_uniform`` contracts),
  suppressions are for cold host-side code.
- **the baseline** — ``tools/repro_lint_baseline.json`` lists known legacy
  findings as ``{path, rule, message}`` records.  Matching ignores line
  numbers, so unrelated edits never resurrect a baselined finding; any
  finding *not* in the baseline fails CI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path

SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w,\- ]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location."""

    path: str          # repo-relative posix path
    line: int          # 1-based source line
    rule: str          # rule id, e.g. "key-reuse"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def key(self) -> tuple:
        """Baseline identity: line numbers are deliberately excluded."""
        return (self.path, self.rule, self.message)


def parse_suppressions(source: str) -> dict[int, set[str]]:
    """Map line number -> set of rule ids disabled on that line.

    ``disable=all`` silences every rule for the line.
    """
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = SUPPRESS_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def is_suppressed(f: Finding, suppressions: dict[int, set[str]]) -> bool:
    rules = suppressions.get(f.line)
    return bool(rules) and (f.rule in rules or "all" in rules)


def count_suppressions(source: str) -> int:
    """Number of inline suppression comments in ``source`` (the self-check
    pins this to zero for core/ and kernels/)."""
    return len(parse_suppressions(source))


def load_baseline(path: str | Path) -> set[tuple]:
    """Load the committed baseline as a set of :meth:`Finding.key` tuples."""
    p = Path(path)
    if not p.exists():
        return set()
    records = json.loads(p.read_text())
    return {(r["path"], r["rule"], r["message"]) for r in records}


def write_baseline(findings: list[Finding], path: str | Path) -> None:
    """Write ``findings`` as a fresh baseline file (``--write-baseline``)."""
    records = [dict(path=f.path, rule=f.rule, message=f.message)
               for f in sorted(set(findings))]
    Path(path).write_text(json.dumps(records, indent=2, sort_keys=True) + "\n")


def split_baselined(findings: list[Finding], baseline: set[tuple]
                    ) -> tuple[list[Finding], list[Finding]]:
    """Partition into (new, baselined).  A baseline record matches every
    finding with the same (path, rule, message) regardless of line."""
    new, old = [], []
    for f in findings:
        (old if f.key() in baseline else new).append(f)
    return new, old
