"""id-overflow: packed-id arithmetic without explicit int64 promotion.

Grounded in PR 3's bug: ``u * n + v`` on int32 vertex ids silently wraps
once ``n * maxid`` crosses 2**31 (RMAT scale >= 32), producing a *valid
looking* but wrong edge key.  numpy's NEP-50 promotion keeps the int32
dtype when one side is a python int, so the overflow is invisible until
the graph is large enough — exactly the failure a static check catches
and a test on small graphs cannot.

The rule fires on additive combinations of a multiplicative id term —
``X * S + Y`` (any nesting, e.g. ``ii * ny * nz + jj * nz + kk``) — when

- the multiplication mixes an id-like name (``u``, ``v``, ``src``,
  ``dst``, ``row``, ``vid``, ``cid``, ``ii`` ...) with a size-like name
  (``n``, ``cols``, ``grid_n``, ``n_global`` ...), and
- no node of the expression promotes to a 64-bit dtype
  (``.astype(np.int64)``, ``np.int64(...)``, ``dtype=np.int64`` ...) or
  routes through the id policy (``.astype(pol.id_dtype)`` — the policy
  widens exactly when the packing would wrap, see ``graph.id_policy``).

Pure size-by-size arithmetic (``n_local_max * maxd``) and already-promoted
packings stay quiet.
"""
from __future__ import annotations

import ast
import re

from .findings import Finding

ID_NAMES = {"u", "v", "src", "dst", "row", "rows", "col", "vid", "vids",
            "cid", "gid", "nid", "eid", "ii", "jj", "kk", "ni", "nj", "nk",
            "iu", "iv", "owner", "slot", "idx", "ids", "node", "vertex",
            "edge_src", "edge_dst", "indices"}
SIZE_NAMES = {"n", "cols", "ncols", "grid_n", "ny", "nz", "nx", "n_global",
              "n_total", "num_nodes", "n_nodes", "width", "stride",
              "n_cols", "dim", "side", "m"}
PROMOTED = re.compile(r"int64|uint64|i8\b|int_\b|id_dtype|ell_dtype")


def _names(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)} | {
        n.attr for n in ast.walk(node) if isinstance(n, ast.Attribute)}


def _is_promoted(node: ast.AST) -> bool:
    """Any 64-bit promotion inside the expression silences the rule."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Attribute) and f.attr == "astype":
                if any(PROMOTED.search(ast.unparse(a)) for a in n.args):
                    return True
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            if PROMOTED.search(name or ""):
                return True
            for kw in n.keywords:
                if kw.arg == "dtype" and PROMOTED.search(
                        ast.unparse(kw.value)):
                    return True
        if isinstance(n, ast.Attribute) and PROMOTED.search(n.attr):
            return True
    return False


def _id_mult(node: ast.AST) -> bool:
    """Is ``node`` (or a sub-product) an id-name times a size-name?"""
    for n in ast.walk(node):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mult):
            left, right = _names(n.left), _names(n.right)
            if ((left & ID_NAMES and right & SIZE_NAMES)
                    or (right & ID_NAMES and left & SIZE_NAMES)):
                return True
    return False


def check_id_overflow(ctx) -> list[Finding]:
    findings = []
    covered: set[int] = set()     # descendants of an already-reported Add
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add)):
            continue
        if id(node) in covered:
            continue
        mult_side, other = None, None
        if _id_mult(node.left):
            mult_side, other = node.left, node.right
        elif _id_mult(node.right):
            mult_side, other = node.right, node.left
        if mult_side is None:
            continue
        if not (_names(other) & ID_NAMES):
            continue
        if _is_promoted(node):
            continue
        covered.update(id(n) for n in ast.walk(node)
                       if isinstance(n, ast.BinOp))
        expr = ast.unparse(node)
        if len(expr) > 60:
            expr = expr[:57] + "..."
        findings.append(Finding(
            ctx.path, node.lineno, "id-overflow",
            f"id packing '{expr}' combines id and size without explicit "
            f"int64 promotion (wraps at 2**31, cf. PR 3)"))
    return findings
