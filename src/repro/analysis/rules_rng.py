"""key-reuse: the same PRNG key consumed by two samplers without a
``split``/``fold_in`` in between.

Grounded in PR 4's bug class: a replayed key makes "independent" random
permutations identical, which silently degrades recoloring quality while
every test that checks *validity* still passes.  Two patterns fire:

1. **linear reuse** — within one function, a key-typed name is passed to a
   second sampler (``jax.random.bits``/``uniform``/``permutation``/...)
   without being re-derived (``split``/``fold_in``) or re-bound since its
   first consumption.
2. **loop reuse** — a sampler inside a python ``for``/``while`` consumes a
   key that is never re-derived inside the loop body (the canonical fix is
   ``ikey = jax.random.fold_in(key, i)`` per iteration).

Only names *proven* key-typed are tracked (assigned from
``PRNGKey``/``key``/``split``/``fold_in``, or parameters named like keys),
so ordinary arrays passed to two functions never false-positive.
"""
from __future__ import annotations

import ast
import re

from .findings import Finding

# jax.random samplers that consume (and thus "use up") a key
SAMPLERS = {"bits", "uniform", "normal", "randint", "permutation", "choice",
            "bernoulli", "categorical", "gamma", "beta", "dirichlet",
            "exponential", "gumbel", "laplace", "truncated_normal",
            "shuffle", "rademacher", "poisson", "binomial", "ball",
            "cauchy", "maxwell", "orthogonal", "t"}
# calls that *derive* fresh keys (never consume)
DERIVERS = {"split", "fold_in"}
MAKERS = {"PRNGKey", "key"}
KEYLIKE_PARAM = re.compile(r"(^|_)(key|keys|rng|prngkey)s?($|\d)", re.I)


def _sampler_name(call: ast.Call) -> str | None:
    """Name of the jax.random sampler if this call is one, else None."""
    f = call.func
    name = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None)
    if name is None:
        return None
    if name in DERIVERS or name in MAKERS:
        return None
    if name not in SAMPLERS:
        return None
    # require a `random`-ish receiver (jax.random.bits / jrandom.bits) or a
    # bare from-import name; `x.permutation` on arbitrary objects is skipped
    # unless the receiver mentions random.
    if isinstance(f, ast.Attribute):
        recv = ast.unparse(f.value)
        if "random" not in recv and recv not in ("jr", "jrnd", "jrandom"):
            return None
    return name


def _call_kind(call: ast.Call) -> str | None:
    f = call.func
    name = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None)
    if name in MAKERS and (not isinstance(f, ast.Attribute)
                           or "random" in ast.unparse(f.value)):
        return "maker"
    if name in DERIVERS:
        return "deriver"
    if _sampler_name(call):
        return "sampler"
    return None


def _key_args(call: ast.Call) -> list[str]:
    """Key-candidate Name arguments of a sampler/deriver call."""
    out = []
    for a in list(call.args) + [k.value for k in call.keywords]:
        if isinstance(a, ast.Name):
            out.append(a.id)
    return out


class _FuncScan:
    """Linear consumed-state scan of one function body."""

    def __init__(self, path: str, findings: list[Finding]):
        self.path = path
        self.findings = findings
        # name -> line of first consumption (None = tracked, not consumed)
        self.state: dict[str, int | None] = {}

    def track(self, name: str) -> None:
        self.state[name] = None

    def untrack(self, name: str) -> None:
        self.state.pop(name, None)

    def handle_call(self, call: ast.Call) -> None:
        kind = _call_kind(call)
        if kind == "sampler":
            for name in _key_args(call):
                if name not in self.state:
                    continue
                first = self.state[name]
                if first is not None:
                    self.findings.append(Finding(
                        self.path, call.lineno, "key-reuse",
                        f"PRNG key '{name}' consumed again without "
                        f"split/fold_in (first consumed on line {first})"))
                else:
                    self.state[name] = call.lineno

    def handle_assign_targets(self, targets: list[ast.expr],
                              value: ast.expr) -> None:
        kind = _call_kind(value) if isinstance(value, ast.Call) else None
        for t in targets:
            names = []
            if isinstance(t, ast.Name):
                names = [t.id]
            elif isinstance(t, (ast.Tuple, ast.List)):
                names = [e.id for e in t.elts if isinstance(e, ast.Name)]
            for n in names:
                if kind in ("maker", "deriver"):
                    self.track(n)         # fresh key value
                elif n in self.state:
                    self.untrack(n)       # rebound to something else

    def scan(self, stmts: list[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue                  # nested defs scanned separately
            if isinstance(st, ast.If):
                # consumption on exclusive branches is not a replay: scan
                # each arm from a copy, then merge consumed lines
                self._visit_expr(st.test)
                pre = dict(self.state)
                self.scan(st.body)
                s1 = self.state
                self.state = dict(pre)
                self.scan(st.orelse)
                s2 = self.state
                merged = {}
                for n in set(s1) | set(s2):
                    a, b = s1.get(n, pre.get(n)), s2.get(n, pre.get(n))
                    if n in s1 or n in s2:
                        merged[n] = a if a is not None else b
                self.state = merged
                continue
            if isinstance(st, (ast.For, ast.While)):
                self._scan_loop(st)
                continue
            if isinstance(st, ast.Try):
                self.scan(st.body)
                for h in st.handlers:
                    self.scan(h.body)
                self.scan(st.orelse)
                self.scan(st.finalbody)
                continue
            if isinstance(st, ast.Assign):
                self._visit_expr(st.value)
                self.handle_assign_targets(st.targets, st.value)
                continue
            if isinstance(st, ast.AnnAssign) and st.value is not None:
                self._visit_expr(st.value)
                self.handle_assign_targets([st.target], st.value)
                continue
            if isinstance(st, ast.With):
                self.scan(st.body)
                continue
            for n in ast.walk(st):
                if isinstance(n, ast.expr):
                    self._visit_expr(n)
                    break

    def _visit_expr(self, expr: ast.expr) -> None:
        for n in ast.walk(expr):
            if isinstance(n, ast.Call):
                self.handle_call(n)

    def _scan_loop(self, st: ast.For | ast.While) -> None:
        # names re-derived or re-bound anywhere inside the loop body
        rebound: set[str] = set()
        if isinstance(st, ast.For):
            for n in ast.walk(st.target):
                if isinstance(n, ast.Name):
                    rebound.add(n.id)
        for n in ast.walk(st):
            if isinstance(n, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                tgts = n.targets if isinstance(n, ast.Assign) else [n.target]
                for t in tgts:
                    for m in ast.walk(t):
                        if isinstance(m, ast.Name):
                            rebound.add(m.id)
        for n in ast.walk(st):
            if isinstance(n, ast.Call) and _call_kind(n) == "sampler":
                for name in _key_args(n):
                    if name in self.state and name not in rebound:
                        self.findings.append(Finding(
                            self.path, n.lineno, "key-reuse",
                            f"PRNG key '{name}' sampled inside a loop "
                            f"without a per-iteration fold_in/split"))
        # loop body consumption still updates linear state (one pass)
        self.scan(st.body)
        self.scan(st.orelse)


def check_key_reuse(ctx) -> list[Finding]:
    findings: list[Finding] = []
    for fn in [n for n in ast.walk(ctx.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Module))]:
        scan = _FuncScan(ctx.path, findings)
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for a in (list(fn.args.posonlyargs) + list(fn.args.args)
                      + list(fn.args.kwonlyargs)):
                if KEYLIKE_PARAM.search(a.arg):
                    scan.track(a.arg)
        scan.scan(fn.body)
    return findings
