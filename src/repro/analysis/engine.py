"""Rule registry + file walker: the AST layer of repro-lint.

``run_lint`` is the library entry point (the ``tools.repro_lint`` CLI and
the CI job are thin wrappers): walk the targets, parse each python file
once, run the shard-uniformity analysis once, hand the shared context to
every rule, then subtract inline suppressions and the committed baseline.
"""
from __future__ import annotations

import dataclasses
import sys
from pathlib import Path

from . import rules_numeric, rules_rng, rules_spmd, uniformity
from .findings import (Finding, is_suppressed, load_baseline,
                       parse_suppressions, split_baselined)

#: rule id -> checker.  Checkers take a :class:`FileContext` and return
#: findings; ids are what suppressions and the baseline refer to.
RULES = {
    "key-reuse": rules_rng.check_key_reuse,
    "id-overflow": rules_numeric.check_id_overflow,
    "host-sync": rules_spmd.check_host_sync,
    "divergent-collective": rules_spmd.check_divergent_collective,
    "nonuniform-loop": rules_spmd.check_nonuniform_loop,
}

# Rules that need the uniformity analysis (skipped when parsing-only rules
# are requested, so fixture tests stay fast).
ANALYSIS_RULES = {"host-sync", "divergent-collective", "nonuniform-loop"}


@dataclasses.dataclass
class FileContext:
    """Everything a rule may look at for one file."""

    path: str                       # repo-relative posix path
    source: str
    tree: object                    # ast.Module
    analysis: object | None         # uniformity.ModuleAnalysis | None


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]         # new (non-baselined, non-suppressed)
    baselined: list[Finding]
    suppressed: int
    n_files: int
    errors: list[str]

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out


def iter_py_files(targets: list[str | Path], root: Path) -> list[Path]:
    files: list[Path] = []
    for t in targets:
        p = (root / t) if not Path(t).is_absolute() else Path(t)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_source(source: str, path: str, rules: list[str] | None = None,
                errors: list[str] | None = None) -> list[Finding]:
    """Lint one in-memory source blob (fixture tests call this directly).

    ``path`` matters: the host-sync rule only applies under
    ``core/``/``kernels/``.  Suppressions are applied, the baseline is not.
    """
    import ast
    rule_ids = list(rules) if rules is not None else list(RULES)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        (errors if errors is not None else []).append(f"{path}: {e}")
        return []
    analysis = None
    if any(r in ANALYSIS_RULES for r in rule_ids):
        try:
            mod = uniformity.ModuleAnalysis(tree, path)
            mod.run()
            analysis = mod
        except RecursionError as e:   # fail open, loudly
            msg = f"{path}: uniformity analysis failed: {e!r}"
            if errors is not None:
                errors.append(msg)
            else:
                print(f"repro-lint: {msg}", file=sys.stderr)
    ctx = FileContext(path=path, source=source, tree=tree, analysis=analysis)
    suppressions = parse_suppressions(source)
    out: list[Finding] = []
    for rid in rule_ids:
        for f in RULES[rid](ctx):
            if not is_suppressed(f, suppressions):
                out.append(f)
    return sorted(out)


def run_lint(targets: list[str | Path], root: str | Path = ".",
             baseline: str | Path | None = None,
             rules: list[str] | None = None) -> LintResult:
    """Lint every ``*.py`` under ``targets`` (paths relative to ``root``)."""
    root = Path(root).resolve()
    errors: list[str] = []
    all_findings: list[Finding] = []
    suppressed = 0
    files = iter_py_files(targets, root)
    for p in files:
        try:
            source = p.read_text()
        except OSError as e:
            errors.append(f"{p}: {e}")
            continue
        rel = p.resolve().relative_to(root).as_posix() \
            if p.resolve().is_relative_to(root) else p.as_posix()
        before = len(parse_suppressions(source))
        suppressed += before
        all_findings.extend(lint_source(source, rel, rules, errors))
    base = load_baseline(baseline) if baseline else set()
    new, old = split_baselined(all_findings, base)
    return LintResult(findings=new, baselined=old, suppressed=suppressed,
                      n_files=len(files), errors=errors)
