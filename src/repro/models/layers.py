"""Parameter definitions + elementary layers (functional, framework-free).

Parameters live in a flat ``{path: array}`` dict. Each architecture declares a
flat ``{path: ParamDef}`` table (shape, dtype, init scale, logical sharding
dims); from that single table we derive real initialization, the
ShapeDtypeStruct tree for the dry-run, and the NamedSharding tree — one source
of truth, no drift between init and distribution.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShardingPlan


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    dims: tuple[str | None, ...]          # logical sharding per dim
    init: str = "normal"                  # normal | zeros | ones
    scale: float | None = None            # None -> 1/sqrt(fan_in)
    dtype: str = "bfloat16"

    def initializer(self, key):
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32)
                * scale).astype(self.dtype)


def init_params(defs: dict[str, ParamDef], key) -> dict[str, jnp.ndarray]:
    keys = jax.random.split(key, len(defs))
    return {name: d.initializer(k)
            for (name, d), k in zip(sorted(defs.items()), keys)}


def param_specs(defs: dict[str, ParamDef], plan: ShardingPlan):
    """{path: PartitionSpec} matching `defs` under the plan."""
    return {name: plan.spec(d.dims, d.shape) for name, d in defs.items()}


def param_shapestructs(defs: dict[str, ParamDef], mesh, plan: ShardingPlan):
    """{path: ShapeDtypeStruct-with-sharding} — dry-run stand-ins."""
    from jax.sharding import NamedSharding
    return {name: jax.ShapeDtypeStruct(
        d.shape, d.dtype, sharding=NamedSharding(mesh, plan.spec(d.dims,
                                                                 d.shape)))
        for name, d in defs.items()}


def count_params(defs: dict[str, ParamDef]) -> int:
    return int(sum(np.prod(d.shape) for d in defs.values()))


# --------------------------------------------------------------------------
# Elementary ops (all take explicit params, compute dtype from inputs)


def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, pos, theta: float = 1e4):
    """x (..., S, H, D), pos (..., S) -> rotated x (half-split convention)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)      # (D/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs            # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x, pos3, sections: tuple[int, int, int], theta: float = 1e4):
    """Qwen2-VL M-RoPE: pos3 (3, ..., S); `sections` split D/2 among t/h/w."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)       # (D/2,)
    sec = np.cumsum((0,) + tuple(sections))
    assert sec[-1] == d // 2, f"M-RoPE sections {sections} != head_dim/2 {d//2}"
    stream = np.zeros(d // 2, np.int32)
    for i in range(3):
        stream[sec[i]:sec[i + 1]] = i
    pos = jnp.take(pos3, jnp.asarray(stream), axis=0)            # (D/2, ..., S)
    pos = jnp.moveaxis(pos, 0, -1)                               # (..., S, D/2)
    ang = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_from_pos(pos, d_model: int):
    """pos (..., S) int -> (..., S, d_model) sinusoidal embedding (f32)."""
    half = d_model // 2
    inv = jnp.asarray(1.0 / (10000 ** (np.arange(half) / half)), jnp.float32)
    ang = pos[..., None].astype(jnp.float32) * inv
    out = jnp.zeros(pos.shape + (d_model,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    return out.at[..., 1::2].set(jnp.cos(ang))


def sinusoidal_pos(seq_len: int, d_model: int):
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / (10000 ** (dim / d_model))
    out = np.zeros((seq_len, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def geglu(x, w_gate, w_up, w_down):
    h = jax.nn.gelu(x @ w_gate, approximate=True) * (x @ w_up)
    return h @ w_down


def constrain(x, plan: ShardingPlan, dims: tuple[str | None, ...]):
    """with_sharding_constraint under the ambient mesh (no-op if no axes)."""
    spec = plan.spec(dims, x.shape)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)
