"""Attention: blockwise (flash-style) SDPA, GQA/MQA, qk-norm, MLA, caches.

Blockwise attention is pure JAX (scan over query blocks × scan over KV
blocks, online softmax, f32 accumulators) so 32k prefill / 4k train never
materialize S×S scores; the backward pass recomputes through the scans under
the block-level remat policy (model.py).

Decode uses a ring-buffer KV cache: capacity = the assignment's ``seq_len``,
`pos % S` overwrite, full-window attention. MLA decode runs in *absorbed*
form — scores and values are computed against the (kv_lora+rope) latent cache
without materializing per-head K/V (the deepseek-v3 trick, memory-bound win).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShardingPlan
from .layers import ParamDef, apply_m_rope, apply_rope, constrain, rms_norm

NEG_INF = -1e30


def _blockwise(q, k, v, *, causal: bool, scale: float, q_block: int = 512,
               kv_block: int = 512):
    """q (B,Sq,H,D), k/v (B,Sk,Hkv,Dk/Dv) -> (B,Sq,H,Dv); online softmax."""
    B, Sq, H, D = q.shape
    Sk, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // Hkv

    def pick(S, target):  # largest block <= target that divides S
        for b in range(min(target, S), 0, -1):
            if S % b == 0:
                return b
        return S

    bq, bk = pick(Sq, q_block), pick(Sk, kv_block)
    nq, nk = Sq // bq, Sk // bk

    qb = q.reshape(B, nq, bq, Hkv, G, D)
    kb = k.reshape(B, nk, bk, Hkv, D)
    vb = v.reshape(B, nk, bk, Hkv, Dv)

    def q_step(_, qi):
        qblk, qidx = qi                                  # (B,bq,Hkv,G,D)
        qpos = qidx * bq + jnp.arange(bq)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                kpos = kidx * bk + jnp.arange(bk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * alpha[..., None] + pv), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        acc0 = jnp.zeros((B, Hkv, G, bq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,Hkv,G,bq,Dv)
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, ob = jax.lax.scan(q_step, None,
                         (qb.swapaxes(0, 1), jnp.arange(nq)))
    # ob (nq, B, bq, Hkv, G, Dv) -> (B, Sq, H, Dv)
    return ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dv)


def _decode_sdpa(q, k, v, scale: float, n_valid=None):
    """q (B,1,H,D) vs cache k/v (B,S,Hkv,D*) -> (B,1,H,Dv).

    `n_valid`: number of filled cache slots (unfilled ones are masked)."""
    B, _, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qh = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k,
                   preferred_element_type=jnp.float32) * scale
    if n_valid is not None:
        s = jnp.where(jnp.arange(S)[None, None, None] < n_valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, v.shape[3]).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA / MQA (+ qk-norm, RoPE / M-RoPE)


def gqa_defs(cfg: ArchConfig, dt: str) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    defs = {
        "wq": ParamDef((d, H * hd), ("fsdp", "tp"), dtype=dt),
        "wk": ParamDef((d, Hkv * hd), ("fsdp", "tp"), dtype=dt),
        "wv": ParamDef((d, Hkv * hd), ("fsdp", "tp"), dtype=dt),
        "wo": ParamDef((H * hd, d), ("tp", "fsdp"), dtype=dt),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="ones", dtype=dt)
        defs["k_norm"] = ParamDef((hd,), (None,), init="ones", dtype=dt)
    return defs


def gqa_apply(p, x, pos, cfg: ArchConfig, plan: ShardingPlan, *,
              causal=True, mode="train", cache=None, cache_pos=None,
              pos3=None):
    """mode: train/prefill (blockwise) | decode (ring-buffer cache)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    if cfg.m_rope and pos3 is not None:
        sections = _mrope_sections(hd)
        q = apply_m_rope(q, pos3, sections, cfg.rope_theta)
        k = apply_m_rope(k, pos3, sections, cfg.rope_theta)
    elif cfg.rope_theta > 0:  # whisper (theta=0) uses absolute positions
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    q = constrain(q, plan, ("batch", None, "tp", None))
    scale = hd ** -0.5

    if mode == "decode":
        S_cache = cache["k"].shape[1]
        slot = cache_pos % S_cache
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(
            cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(
            cache["v"].dtype), (0, slot, 0, 0))
        n_valid = jnp.minimum(cache_pos + 1, S_cache)
        o = _decode_sdpa(q, k_cache, v_cache, scale, n_valid)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        o = _blockwise(q, k, v, causal=causal, scale=scale)
        new_cache = None
        if mode == "prefill":
            if cache is not None:  # write prompt K/V into the cache buffer
                new_cache = {
                    "k": jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))}
            else:
                new_cache = {"k": k.astype(jnp.bfloat16),
                             "v": v.astype(jnp.bfloat16)}
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return constrain(out, plan, ("batch", None, "fsdp")), new_cache


def gqa_cross_apply(p, x, enc_kv, cfg: ArchConfig, plan: ShardingPlan):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    o = _blockwise(q, enc_kv["k"], enc_kv["v"], causal=False,
                   scale=hd ** -0.5)
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return constrain(out, plan, ("batch", None, "fsdp"))


def encode_kv(p, x_enc, cfg: ArchConfig):
    B, S, _ = x_enc.shape
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {"k": (x_enc @ p["wk"]).reshape(B, S, Hkv, hd),
            "v": (x_enc @ p["wv"]).reshape(B, S, Hkv, hd)}


def _mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Qwen2-VL splits D/2 rotary channels among (t, h, w) as 2:3:3."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


# --------------------------------------------------------------------------
# MLA (deepseek-v3 / minicpm3)


def mla_defs(cfg: ArchConfig, dt: str) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    defs = {
        "wkv_a": ParamDef((d, kvl + rope), ("fsdp", None), dtype=dt),
        "kv_norm": ParamDef((kvl,), (None,), init="ones", dtype=dt),
        "wkv_b": ParamDef((kvl, H * (nope + vd)), ("fsdp", "tp"), dtype=dt),
        "wo": ParamDef((H * vd, d), ("tp", "fsdp"), dtype=dt),
    }
    if ql > 0:
        defs["wq_a"] = ParamDef((d, ql), ("fsdp", None), dtype=dt)
        defs["q_norm"] = ParamDef((ql,), (None,), init="ones", dtype=dt)
        defs["wq_b"] = ParamDef((ql, H * (nope + rope)), ("fsdp", "tp"),
                                dtype=dt)
    else:
        defs["wq"] = ParamDef((d, H * (nope + rope)), ("fsdp", "tp"), dtype=dt)
    return defs


def _mla_q(p, x, cfg: ArchConfig):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.rms_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, H, nope + rope)
    return q[..., :nope], q[..., nope:]


def mla_apply(p, x, pos, cfg: ArchConfig, plan: ShardingPlan, *,
              mode="train", cache=None, cache_pos=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, vd, kvl = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                           cfg.kv_lora_rank)
    scale = (nope + rope) ** -0.5

    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]                                 # (B,S,kvl+rope)
    c_kv = rms_norm(kv_a[..., :kvl], p["kv_norm"], cfg.rms_eps)
    k_rope = apply_rope(kv_a[..., kvl:][:, :, None, :], pos,
                        cfg.rope_theta)                   # (B,S,1,rope)

    wkv_b = p["wkv_b"].reshape(kvl, H, nope + vd)
    w_k, w_v = wkv_b[..., :nope], wkv_b[..., nope:]

    if mode == "decode":
        S_cache = cache["c_kv"].shape[1]
        slot = cache_pos % S_cache
        c_cache = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, slot, 0))
        r_cache = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype),
            (0, slot, 0))
        # absorbed scores: q_nope' = q_nope @ w_k^T  -> (B,1,H,kvl)
        q_abs = jnp.einsum("bshn,khn->bshk", q_nope, w_k)
        s = (jnp.einsum("bshk,btk->bhst", q_abs, c_cache,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshr,btr->bhst", q_rope, r_cache,
                          preferred_element_type=jnp.float32)) * scale
        n_valid = jnp.minimum(cache_pos + 1, S_cache)
        s = jnp.where(jnp.arange(S_cache)[None, None, None] < n_valid, s,
                      -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btk->bshk", pr.astype(c_cache.dtype), c_cache,
                           preferred_element_type=jnp.float32)
        o = jnp.einsum("bshk,khv->bshv", o_lat.astype(x.dtype), w_v)
        new_cache = {"c_kv": c_cache, "k_rope": r_cache}
    else:
        # materialized K/V + blockwise attention
        k_nope = jnp.einsum("btk,khn->bthn", c_kv, w_k)
        v = jnp.einsum("btk,khv->bthv", c_kv, w_v)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = constrain(q, plan, ("batch", None, "tp", None))
        o = _blockwise(q, k, v, causal=True, scale=scale)
        new_cache = None
        if mode == "prefill":
            if cache is not None:
                new_cache = {
                    "c_kv": jax.lax.dynamic_update_slice(
                        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                        (0, 0, 0)),
                    "k_rope": jax.lax.dynamic_update_slice(
                        cache["k_rope"],
                        k_rope[:, :, 0].astype(cache["k_rope"].dtype),
                        (0, 0, 0))}
            else:
                new_cache = {"c_kv": c_kv.astype(jnp.bfloat16),
                             "k_rope": k_rope[:, :, 0].astype(jnp.bfloat16)}
    out = o.reshape(B, S, H * vd) @ p["wo"]
    return constrain(out, plan, ("batch", None, "fsdp")), new_cache
