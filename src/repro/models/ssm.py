"""Attention-free sequence mixers: RWKV6 ("Finch") and Mamba (for Jamba).

RWKV6 time-mix uses data-dependent per-channel decays. We implement the
*chunked* parallel form (GLA-style): within a chunk of length C the decays
are handled with cumulative log-decay matrices (f32), across chunks a
recurrent state (B, H, dk, dv) is carried by a scan over S/C steps — the
TPU-friendly formulation (matmuls instead of a length-S scan). A step form
(`rwkv6_step`) serves decode with O(1) state.

Mamba is the classic selective SSM: causal depthwise conv + input-dependent
(dt, B, C) and a diagonal state scan, carried over the sequence by lax.scan
(d_state=16 keeps the state small); decode keeps (conv window, h) as cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShardingPlan
from .layers import ParamDef, constrain, rms_norm

# --------------------------------------------------------------------------
# RWKV6


def rwkv6_defs(cfg: ArchConfig, dt: str) -> dict:
    d = cfg.d_model
    H = max(d // 64, 1)                      # head_size 64 (RWKV convention)
    lora = max(32, d // 32)
    return {
        "w_r": ParamDef((d, d), ("fsdp", "tp"), dtype=dt),
        "w_k": ParamDef((d, d), ("fsdp", "tp"), dtype=dt),
        "w_v": ParamDef((d, d), ("fsdp", "tp"), dtype=dt),
        "w_g": ParamDef((d, d), ("fsdp", "tp"), dtype=dt),
        "w_o": ParamDef((d, d), ("tp", "fsdp"), dtype=dt),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x W_a) W_b))
        "decay_w0": ParamDef((d,), (None,), init="zeros", dtype="float32"),
        "decay_a": ParamDef((d, lora), ("fsdp", None), dtype=dt),
        "decay_b": ParamDef((lora, d), (None, "fsdp"), dtype=dt),
        "bonus_u": ParamDef((d,), (None,), init="zeros", dtype="float32"),
        # token-shift mixing coefficients
        "mix": ParamDef((5, d), (None, None), init="zeros", dtype="float32"),
        "ln_x": ParamDef((d,), (None,), init="ones", dtype=dt),
    }


def _rwkv6_inputs(p, x, x_prev, cfg):
    """Token-shifted projections. x (B,S,d); x_prev (B,1,d) last token of
    previous segment (zeros at sequence start)."""
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)     # shifted
    mix = jax.nn.sigmoid(p["mix"]).astype(x.dtype)        # (5, d)
    def mixed(i):
        return x + (xs - x) * mix[i]
    r = mixed(0) @ p["w_r"]
    k = mixed(1) @ p["w_k"]
    v = mixed(2) @ p["w_v"]
    g = jax.nn.silu(mixed(3) @ p["w_g"])
    lw = (p["decay_w0"]
          + jnp.tanh(mixed(4) @ p["decay_a"]) @ p["decay_b"])
    # log decay in [-5, 0): the lower clamp bounds the intra-chunk exponent
    # (chunk=16 -> |cum| <= 80 < log(f32 max)), exactly as chunked GLA does.
    log_w = -jnp.clip(jnp.exp(jnp.clip(lw.astype(jnp.float32), -10.0, 6.0)),
                      1e-6, 5.0)
    return r, k, v, g, log_w


def rwkv6_chunked(p, x, x_prev, state, cfg: ArchConfig,
                  plan: ShardingPlan, chunk: int = 16):
    """x (B,S,d) -> (y, (x_last, state)). state (B,H,dk,dv) f32."""
    B, S, d = x.shape
    H = max(d // 64, 1)
    dk = dv = d // H
    r, k, v, g, log_w = _rwkv6_inputs(p, x, x_prev, cfg)
    u = p["bonus_u"].reshape(H, dk)

    C = min(chunk, S)
    while S % C != 0:  # largest chunk <= requested that divides S
        C -= 1
    N = S // C

    def reshape_h(t):                                     # (B,S,d)->(N,B,H,C,dk)
        return t.reshape(B, N, C, H, -1).transpose(1, 0, 3, 2, 4)

    rs, ks, vs = reshape_h(r), reshape_h(k), reshape_h(v)
    lws = reshape_h(log_w).astype(jnp.float32)            # (N,B,H,C,dk)

    def chunk_step(state, inp):
        rc, kc, vc, lwc = inp                             # (B,H,C,*)
        cum = jnp.cumsum(lwc, axis=2)                     # inclusive Σ log w
        total = cum[:, :, -1:]                            # (B,H,1,dk)
        # decay of state contribution up to each position (exclusive)
        dec_q = jnp.exp(cum - lwc)                        # Π_{s<t} w_s
        r_dec = (rc.astype(jnp.float32) * dec_q)
        # inter-chunk: r_t · (Π_{s<t} w) · state
        y_inter = jnp.einsum("bhck,bhkv->bhcv", r_dec, state)
        # intra-chunk: pairwise decays Π_{s<t..} via cum differences
        ki = (kc.astype(jnp.float32) * jnp.exp(-cum))     # k_s / Π_{u<=s} w
        # att[t,s] = Σ_k r_t Π_{u<=t-1}w / Π_{u<=s}w · k_s, strictly lower-tri
        att = jnp.einsum("bhck,bhsk->bhcs", r_dec, ki)
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhcs,bhsv->bhcv", att, vc.astype(jnp.float32))
        # current-token bonus u
        y_diag = jnp.einsum("bhck,bhck->bhc", rc.astype(jnp.float32) * u[None, :, None, :],
                            kc.astype(jnp.float32))[..., None] \
            * vc.astype(jnp.float32)
        # state update: S' = diag(Πw) S + Σ_s (Π_{u>s} w ⊙ k_s)^T v_s
        k_dec = kc.astype(jnp.float32) * jnp.exp(total - cum)
        state = (jnp.exp(total).swapaxes(2, 3) * state
                 + jnp.einsum("bhsk,bhsv->bhkv", k_dec,
                              vc.astype(jnp.float32)))
        return state, y_inter + y_intra + y_diag

    state, ys = jax.lax.scan(chunk_step, state, (rs, ks, vs, lws))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, d)      # back to (B,S,d)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.rms_eps) * g
    out = y @ p["w_o"]
    out = constrain(out, plan, ("batch", None, "fsdp"))
    return out, (x[:, -1:], state)


def rwkv6_step(p, x, x_prev, state, cfg: ArchConfig, plan: ShardingPlan):
    """Single-token decode. x (B,1,d); state (B,H,dk,dv)."""
    B, _, d = x.shape
    H = max(d // 64, 1)
    dk = d // H
    r, k, v, g, log_w = _rwkv6_inputs(p, x, x_prev, cfg)
    u = p["bonus_u"].reshape(H, dk)
    rh = r.reshape(B, H, dk).astype(jnp.float32)
    kh = k.reshape(B, H, dk).astype(jnp.float32)
    vh = v.reshape(B, H, dk).astype(jnp.float32)
    w = jnp.exp(log_w.reshape(B, H, dk))
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = jnp.einsum("bhk,bhkv->bhv", rh, state + u[None, :, :, None] * kv)
    state = w[..., None] * state + kv
    y = y.reshape(B, 1, d).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.rms_eps) * g
    return (y @ p["w_o"]), (x, state)


def rwkv6_ffn_defs(cfg: ArchConfig, dt: str) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_k": ParamDef((d, f), ("fsdp", "tp"), dtype=dt),
        "w_v": ParamDef((f, d), ("tp", "fsdp"), dtype=dt),
        "w_r": ParamDef((d, d), ("fsdp", "tp"), dtype=dt),
        "mix": ParamDef((2, d), (None, None), init="zeros", dtype="float32"),
    }


def rwkv6_ffn(p, x, x_prev, cfg: ArchConfig, plan: ShardingPlan):
    """RWKV channel-mix: relu² K, sigmoid receptance gate."""
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mix = jax.nn.sigmoid(p["mix"]).astype(x.dtype)
    xk = x + (xs - x) * mix[0]
    xr = x + (xs - x) * mix[1]
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
    return constrain(out, plan, ("batch", None, "fsdp")), x[:, -1:]


# --------------------------------------------------------------------------
# Mamba (selective SSM, for Jamba)


def mamba_defs(cfg: ArchConfig, dt: str) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    ds, dc = cfg.d_state, cfg.d_conv
    dt_rank = max(d // 16, 1)
    return {
        "w_in": ParamDef((d, 2 * di), ("fsdp", "tp"), dtype=dt),
        "conv_w": ParamDef((dc, di), (None, "tp"), scale=0.5, dtype=dt),
        "conv_b": ParamDef((di,), ("tp",), init="zeros", dtype=dt),
        "w_xdt": ParamDef((di, dt_rank), ("tp", None), dtype=dt),
        "w_dt": ParamDef((dt_rank, di), (None, "tp"), dtype=dt),
        "dt_bias": ParamDef((di,), ("tp",), init="zeros", dtype="float32"),
        "w_bc": ParamDef((di, 2 * ds), ("tp", None), dtype=dt),
        "log_a": ParamDef((di, ds), ("tp", None), init="zeros",
                          dtype="float32"),
        "d_skip": ParamDef((di,), ("tp",), init="ones", dtype="float32"),
        "w_out": ParamDef((di, d), ("tp", "fsdp"), dtype=dt),
    }


def _mamba_bcdt(p, u):
    """u (..., di) -> dt (softplus), B, C."""
    ds = p["log_a"].shape[1]
    dt = jax.nn.softplus(
        (u @ p["w_xdt"]) @ p["w_dt"]
        + p["dt_bias"].astype(u.dtype)).astype(jnp.float32)
    bc = u @ p["w_bc"]
    return dt, bc[..., :ds].astype(jnp.float32), bc[..., ds:].astype(jnp.float32)


def mamba_apply(p, x, conv_state, h_state, cfg: ArchConfig,
                plan: ShardingPlan):
    """x (B,S,d) -> (y, (conv_state, h_state)). h (B,di,ds) f32,
    conv_state (B, d_conv-1, di)."""
    B, S, d = x.shape
    di = cfg.expand * d
    dc = cfg.d_conv
    xz = x @ p["w_in"]
    u, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv over the sequence
    u_pad = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    new_conv_state = u_pad[:, -(dc - 1):]
    stack = jnp.stack([u_pad[:, i:i + S] for i in range(dc)], axis=-1)
    u = jnp.einsum("bsdc,cd->bsd", stack, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(u)

    dt, Bm, Cm = _mamba_bcdt(p, u)                        # (B,S,di),(B,S,ds)
    A = -jnp.exp(p["log_a"])                              # (di, ds)

    # chunked selective scan: materializing exp(dt·A) over the full sequence
    # is (B,S,di,ds) — 67 GB/layer/device for jamba train_4k. Chunk S so the
    # working set is (B,ck,di,ds) while the recurrence stays exact.
    ck = 128
    while S % ck != 0:
        ck -= 1
    nc = S // ck

    def chunk(h, inp):
        dt_c, u_c, B_c, C_c = inp                        # (B,ck,…)
        dA = jnp.exp(dt_c[..., None] * A)                # (B,ck,di,ds)
        dBu = (dt_c * u_c)[..., None] * B_c[:, :, None, :]

        def step(h, t_inp):
            dA_t, dBu_t, C_t = t_inp
            h = dA_t * h + dBu_t                         # (B,di,ds)
            return h, jnp.einsum("bds,bs->bd", h, C_t)

        h, ys = jax.lax.scan(
            step, h, (dA.swapaxes(0, 1), dBu.swapaxes(0, 1),
                      C_c.swapaxes(0, 1)))
        return h, ys                                      # ys (ck,B,di)

    def to_chunks(t):                                     # (B,S,…)->(nc,B,ck,…)
        return t.reshape((B, nc, ck) + t.shape[2:]).swapaxes(0, 1)

    h_state, ys = jax.lax.scan(
        chunk, h_state,
        (to_chunks(dt), to_chunks(u.astype(jnp.float32)), to_chunks(Bm),
         to_chunks(Cm)))
    # ys (nc, ck, B, di) -> (B, S, di)
    y = ys.transpose(2, 0, 1, 3).reshape(B, S, -1) \
        + u.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return constrain(y, plan, ("batch", None, "fsdp")), \
        (new_conv_state.astype(x.dtype), h_state)


def mamba_step(p, x, conv_state, h_state, cfg: ArchConfig,
               plan: ShardingPlan):
    """Single-token decode; same caches as mamba_apply."""
    y, (conv_state, h_state) = mamba_apply(p, x, conv_state, h_state, cfg,
                                           plan)
    return y, (conv_state, h_state)
