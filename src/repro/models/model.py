"""Model assembly: layer plans, parameter tables, train/prefill/decode.

An architecture lowers to a list of *runs*: maximal contiguous groups of
identical (mixer, ffn) layer specs. Each run's parameters are stacked on a
leading L axis and executed with ``lax.scan`` (one HLO body per distinct
block shape — compile time stays flat in depth), rematerialized per block in
training. Heterogeneous stacks (jamba's 1:7 mamba:attention interleave with
alternating MoE) simply produce many short runs.

Modes: ``train`` (loss), ``prefill`` (build caches + last-position logits),
``decode`` (one token against ring-buffer caches).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShardingPlan
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .layers import (ParamDef, constrain, geglu, layer_norm, rms_norm,
                     sinusoidal_from_pos, swiglu)


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str            # gqa | mla | rwkv6 | mamba | none
    ffn: str              # swiglu | geglu | mlp | moe | rwkv
    cross: bool = False   # whisper decoder cross-attention
    causal: bool = True


# --------------------------------------------------------------------------
# Layer plans


def layer_specs(cfg: ArchConfig) -> list[BlockSpec]:
    """Per-layer BlockSpec for the decoder/backbone stack."""
    specs = []
    for i in range(cfg.n_layers):
        if cfg.family == "hybrid":
            mixer = "gqa" if cfg.attn_every and i % cfg.attn_every == (
                cfg.attn_every // 2) else "mamba"
            ffn = "moe" if cfg.moe_every and i % cfg.moe_every == 1 else \
                cfg.ffn_kind
        elif cfg.family == "ssm":
            mixer, ffn = cfg.ssm_kind, cfg.ffn_kind
        else:
            mixer = cfg.attn_kind
            ffn = "moe" if (cfg.is_moe and i >= cfg.first_k_dense) else \
                cfg.ffn_kind
        specs.append(BlockSpec(mixer=mixer, ffn=ffn,
                               cross=cfg.enc_dec, causal=True))
    return specs


def layer_runs(cfg: ArchConfig) -> list[tuple[BlockSpec, int]]:
    runs: list[tuple[BlockSpec, int]] = []
    for s in layer_specs(cfg):
        if runs and runs[-1][0] == s:
            runs[-1] = (s, runs[-1][1] + 1)
        else:
            runs.append((s, 1))
    return runs


def encoder_runs(cfg: ArchConfig) -> list[tuple[BlockSpec, int]]:
    if not cfg.enc_dec:
        return []
    return [(BlockSpec(mixer="gqa", ffn="mlp", causal=False),
             cfg.n_enc_layers)]


# --------------------------------------------------------------------------
# Parameter tables


def _norm_defs(cfg: ArchConfig, dt: str) -> dict:
    if cfg.enc_dec:  # whisper uses LayerNorm
        return {"gamma": ParamDef((cfg.d_model,), (None,), init="ones",
                                  dtype=dt),
                "beta": ParamDef((cfg.d_model,), (None,), init="zeros",
                                 dtype=dt)}
    return {"gamma": ParamDef((cfg.d_model,), (None,), init="ones", dtype=dt)}


def _apply_norm(p, x, cfg: ArchConfig):
    if "beta" in p:
        return layer_norm(x, p["gamma"], p["beta"])
    return rms_norm(x, p["gamma"], cfg.rms_eps)


def _mixer_defs(kind: str, cfg: ArchConfig, dt: str) -> dict:
    if kind == "gqa":
        return attn.gqa_defs(cfg, dt)
    if kind == "mla":
        return attn.mla_defs(cfg, dt)
    if kind == "rwkv6":
        return ssm.rwkv6_defs(cfg, dt)
    if kind == "mamba":
        return ssm.mamba_defs(cfg, dt)
    return {}


def _ffn_defs(kind: str, cfg: ArchConfig, dt: str) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if kind in ("swiglu", "geglu"):
        return {"w_gate": ParamDef((d, f), ("fsdp", "tp"), dtype=dt),
                "w_up": ParamDef((d, f), ("fsdp", "tp"), dtype=dt),
                "w_down": ParamDef((f, d), ("tp", "fsdp"), dtype=dt)}
    if kind == "mlp":
        return {"w1": ParamDef((d, f), ("fsdp", "tp"), dtype=dt),
                "w2": ParamDef((f, d), ("tp", "fsdp"), dtype=dt)}
    if kind == "moe":
        return moe_mod.moe_defs(cfg, dt)
    if kind == "rwkv":
        return ssm.rwkv6_ffn_defs(cfg, dt)
    raise ValueError(kind)


def block_defs(spec: BlockSpec, cfg: ArchConfig, dt: str) -> dict:
    defs = {
        "norm1": _norm_defs(cfg, dt),
        "mixer": _mixer_defs(spec.mixer, cfg, dt),
        "norm2": _norm_defs(cfg, dt),
        "ffn": _ffn_defs(spec.ffn, cfg, dt),
    }
    if spec.cross:
        defs["norm_x"] = _norm_defs(cfg, dt)
        defs["cross"] = attn.gqa_defs(cfg, dt)
    return defs


def _stack_defs(tree, L: int):
    return jax.tree.map(
        lambda d: ParamDef((L,) + d.shape, (None,) + d.dims, d.init, d.scale,
                           d.dtype),
        tree, is_leaf=lambda t: isinstance(t, ParamDef))


def param_defs(cfg: ArchConfig) -> dict:
    dt = cfg.params_dtype
    V = cfg.vocab_padded()
    d = cfg.d_model
    defs: dict[str, Any] = {
        "embed": ParamDef((V, d), ("tp", "fsdp"), scale=1.0, dtype=dt),
        "final_norm": _norm_defs(cfg, dt),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, V), ("fsdp", "tp"), dtype=dt)
    for r, (spec, L) in enumerate(layer_runs(cfg)):
        defs[f"run{r}"] = _stack_defs(block_defs(spec, cfg, dt), L)
    if cfg.enc_dec:
        for r, (spec, L) in enumerate(encoder_runs(cfg)):
            defs[f"enc_run{r}"] = _stack_defs(block_defs(spec, cfg, dt), L)
        defs["enc_final_norm"] = _norm_defs(cfg, dt)
    return defs


# --------------------------------------------------------------------------
# Caches


def _mixer_cache_defs(kind: str, cfg: ArchConfig, B: int, S: int) -> dict:
    d = cfg.d_model
    dt = cfg.compute_dtype
    if kind == "gqa":
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        return {"k": ParamDef((B, S, hkv, hd), ("batch", "seq", None, None),
                              init="zeros", dtype=dt),
                "v": ParamDef((B, S, hkv, hd), ("batch", "seq", None, None),
                              init="zeros", dtype=dt)}
    if kind == "mla":
        return {"c_kv": ParamDef((B, S, cfg.kv_lora_rank),
                                 ("batch", "seq", None), init="zeros",
                                 dtype=dt),
                "k_rope": ParamDef((B, S, cfg.qk_rope_dim),
                                   ("batch", "seq", None), init="zeros",
                                   dtype=dt)}
    if kind == "rwkv6":
        H = max(d // 64, 1)
        return {"x_prev": ParamDef((B, 1, d), ("batch", None, None),
                                   init="zeros", dtype=dt),
                "state": ParamDef((B, H, d // H, d // H),
                                  ("batch", "tp", None, None), init="zeros",
                                  dtype="float32")}
    if kind == "mamba":
        di = cfg.expand * d
        return {"conv": ParamDef((B, cfg.d_conv - 1, di),
                                 ("batch", None, "tp"), init="zeros",
                                 dtype=dt),
                "h": ParamDef((B, di, cfg.d_state), ("batch", "tp", None),
                              init="zeros", dtype="float32")}
    return {}


def cache_defs(cfg: ArchConfig, B: int, S: int) -> dict:
    """Nested ParamDef table for the decode cache (stacked per run)."""
    out: dict[str, Any] = {"pos": ParamDef((), (), init="zeros",
                                           dtype="int32")}
    for r, (spec, L) in enumerate(layer_runs(cfg)):
        entry = {"mixer": _mixer_cache_defs(spec.mixer, cfg, B, S)}
        if spec.ffn == "rwkv":
            entry["ffn"] = {"x_prev": ParamDef((B, 1, cfg.d_model),
                                               ("batch", None, None),
                                               init="zeros",
                                               dtype=cfg.compute_dtype)}
        if spec.cross:
            hkv, hd = cfg.n_kv_heads, cfg.head_dim_
            E = cfg.enc_len
            entry["cross"] = {
                "k": ParamDef((B, E, hkv, hd), ("batch", None, None, None),
                              init="zeros", dtype=cfg.compute_dtype),
                "v": ParamDef((B, E, hkv, hd), ("batch", None, None, None),
                              init="zeros", dtype=cfg.compute_dtype)}
        out[f"run{r}"] = _stack_defs(entry, L)
    return out


def init_cache(cfg: ArchConfig, B: int, S: int):
    return jax.tree.map(lambda d: jnp.zeros(d.shape, d.dtype),
                        cache_defs(cfg, B, S),
                        is_leaf=lambda t: isinstance(t, ParamDef))


# --------------------------------------------------------------------------
# Forward


def _apply_mixer(spec: BlockSpec, p, h, pos, cfg, plan, mode, cache,
                 cache_pos, pos3):
    if spec.mixer == "gqa":
        return attn.gqa_apply(p, h, pos, cfg, plan, causal=spec.causal,
                              mode=mode, cache=cache, cache_pos=cache_pos,
                              pos3=pos3)
    if spec.mixer == "mla":
        return attn.mla_apply(p, h, pos, cfg, plan, mode=mode, cache=cache,
                              cache_pos=cache_pos)
    if spec.mixer == "rwkv6":
        x_prev = cache["x_prev"].astype(h.dtype) if cache is not None else \
            jnp.zeros_like(h[:, :1])
        state = cache["state"] if cache is not None else jnp.zeros(
            (h.shape[0], max(cfg.d_model // 64, 1), 64, 64), jnp.float32)
        if mode == "decode":
            y, (xl, st) = ssm.rwkv6_step(p, h, x_prev, state, cfg, plan)
        else:
            y, (xl, st) = ssm.rwkv6_chunked(p, h, x_prev, state, cfg, plan)
        new_cache = ({"x_prev": xl.astype(cfg.compute_dtype), "state": st}
                     if mode != "train" else None)
        return y, new_cache
    if spec.mixer == "mamba":
        di = cfg.expand * cfg.d_model
        conv = cache["conv"] if cache is not None else jnp.zeros(
            (h.shape[0], cfg.d_conv - 1, di), jnp.bfloat16)
        hs = cache["h"] if cache is not None else jnp.zeros(
            (h.shape[0], di, cfg.d_state), jnp.float32)
        y, (conv, hs) = ssm.mamba_apply(p, h, conv, hs, cfg, plan)
        new_cache = {"conv": conv, "h": hs} if mode != "train" else None
        return y, new_cache
    raise ValueError(spec.mixer)


def _apply_ffn(spec: BlockSpec, p, h, cfg, plan, mode, cache):
    if spec.ffn == "swiglu":
        return swiglu(h, p["w_gate"], p["w_up"], p["w_down"]), 0.0, None
    if spec.ffn == "geglu":
        return geglu(h, p["w_gate"], p["w_up"], p["w_down"]), 0.0, None
    if spec.ffn == "mlp":
        return jax.nn.gelu(h @ p["w1"], approximate=True) @ p["w2"], 0.0, None
    if spec.ffn == "moe":
        y, aux = moe_mod.moe_apply(p, h, cfg, plan)
        return y, aux, None
    if spec.ffn == "rwkv":
        x_prev = cache["x_prev"].astype(h.dtype) if cache is not None else \
            jnp.zeros_like(h[:, :1])
        y, xl = ssm.rwkv6_ffn(p, h, x_prev, cfg, plan)
        new_cache = ({"x_prev": xl.astype(cfg.compute_dtype)}
                     if mode != "train" else None)
        return y, 0.0, new_cache
    raise ValueError(spec.ffn)


def apply_block(spec: BlockSpec, p, x, pos, cfg, plan, *, mode,
                cache=None, cache_pos=None, pos3=None, x_enc=None):
    """One transformer/SSM block. Returns (x, aux, new_cache)."""
    c_mix = cache.get("mixer") if cache else None
    c_ffn = cache.get("ffn") if cache else None
    h = _apply_norm(p["norm1"], x, cfg)
    y, new_mix = _apply_mixer(spec, p["mixer"], h, pos, cfg, plan, mode,
                              c_mix, cache_pos, pos3)
    x = x + y
    new_cache: dict[str, Any] = {}
    if new_mix is not None:
        new_cache["mixer"] = new_mix
    if spec.cross:
        h = _apply_norm(p["norm_x"], x, cfg)
        if mode == "train" or (mode == "prefill" and x_enc is not None):
            enc_kv = attn.encode_kv(p["cross"], x_enc, cfg)
        else:
            enc_kv = {"k": cache["cross"]["k"], "v": cache["cross"]["v"]}
        x = x + attn.gqa_cross_apply(p["cross"], h, enc_kv, cfg, plan)
        if mode == "prefill":
            new_cache["cross"] = {k: v.astype(cfg.compute_dtype)
                                  for k, v in enc_kv.items()}
        elif mode == "decode":
            new_cache["cross"] = cache["cross"]
    h = _apply_norm(p["norm2"], x, cfg)
    y, aux, new_ffn = _apply_ffn(spec, p["ffn"], h, cfg, plan, mode, c_ffn)
    if new_ffn is not None:
        new_cache["ffn"] = new_ffn
    return x + y, aux, (new_cache if new_cache else None)


def _run_stack(spec: BlockSpec, p_stacked, x, pos, cfg, plan, *, mode,
               cache=None, cache_pos=None, pos3=None, x_enc=None):
    """Scan one run (stacked params / caches). Returns (x, aux, new_cache)."""

    def body(carry, xs):
        x, aux = carry
        p_l, c_l = xs
        x, a, nc = apply_block(spec, p_l, x, pos, cfg, plan, mode=mode,
                               cache=c_l, cache_pos=cache_pos, pos3=pos3,
                               x_enc=x_enc)
        if cfg.seq_parallel_acts and mode == "train":
            # Megatron-SP: the saved residual (the scan carry the backward
            # pass keeps per layer) is sharded over (batch x model) — the
            # dominant activation-memory term drops by the TP degree
            x = constrain(x, plan, ("batch", "act_seq", None))
        return (x, aux + a), nc

    if cfg.remat and mode == "train":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       (p_stacked, cache))
    return x, aux, new_cache


def _embed(params, tokens, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:  # gemma convention
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x.astype(cfg.compute_dtype)


def _unembed(params, x, cfg: ArchConfig, plan: ShardingPlan):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    return constrain(logits, plan, ("batch", None, "tp"))


def _encoder(params, batch, cfg, plan):
    x = batch["enc_embeds"].astype(cfg.compute_dtype)
    x = x + sinusoidal_from_pos(jnp.arange(x.shape[1]),
                                cfg.d_model).astype(x.dtype)
    for r, (spec, L) in enumerate(encoder_runs(cfg)):
        x, _, _ = _run_stack(spec, params[f"enc_run{r}"], x,
                             jnp.arange(x.shape[1])[None], cfg, plan,
                             mode="train", cache=None)
    return _apply_norm(params["enc_final_norm"], x, cfg)


def backbone(params, tokens, pos, cfg, plan, *, mode, cache=None,
             pos3=None, batch=None):
    """Shared trunk. Returns (hidden, aux, new_cache)."""
    x = _embed(params, tokens, cfg)
    if cfg.n_patches and batch is not None and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    if cfg.enc_dec:  # whisper decoder: absolute positions, any mode
        x = x + sinusoidal_from_pos(pos, cfg.d_model).astype(x.dtype)
    x = constrain(x, plan, ("batch", None, None))
    x_enc = _encoder(params, batch, cfg, plan) \
        if cfg.enc_dec and mode in ("train", "prefill") else None

    aux = jnp.float32(0.0)
    new_cache = {}
    cache_pos = cache["pos"] if cache is not None else None
    for r, (spec, L) in enumerate(layer_runs(cfg)):
        c = cache.get(f"run{r}") if cache is not None else None
        x, a, nc = _run_stack(spec, params[f"run{r}"], x, pos, cfg, plan,
                              mode=mode, cache=c, cache_pos=cache_pos,
                              pos3=pos3, x_enc=x_enc)
        aux = aux + a
        if nc is not None:
            new_cache[f"run{r}"] = nc
    x = _apply_norm(params["final_norm"], x, cfg)
    if mode != "train":
        new_cache["pos"] = (cache_pos + (1 if mode == "decode" else
                                         tokens.shape[1])) \
            if cache_pos is not None else jnp.int32(tokens.shape[1])
    return x, aux, new_cache


# --------------------------------------------------------------------------
# Entry points


def _xent_chunked(x, w, labels, plan: ShardingPlan, chunk: int = 512):
    """Sequence-chunked softmax xent: never keeps (B,S,V) logits alive.

    Each chunk's (B,c,V) logits are recomputed in the backward pass
    (jax.checkpoint), bounding activation memory at (B,chunk,V/tp)."""
    B, S, d = x.shape
    c = min(chunk, S)
    n = S // c
    assert S % c == 0

    @jax.checkpoint
    def one(xc, lc):
        logits = jnp.einsum("bsd,dv->bsv", xc, w,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, plan, ("batch", None, "tp"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (lse - gold).sum(), (lse ** 2).sum()

    def body(carry, xs):
        nll, z2 = one(*xs)
        return (carry[0] + nll, carry[1] + z2), None

    (nll, z2), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)),
        (x.reshape(B, n, c, d).swapaxes(0, 1),
         labels.reshape(B, n, c).swapaxes(0, 1)))
    denom = B * S
    return nll / denom, z2 / denom


def loss_fn(params, batch, cfg: ArchConfig, plan: ShardingPlan):
    """Causal-LM cross entropy (+ MoE aux). batch: tokens, labels [+stubs]."""
    tokens = batch["tokens"]
    pos = batch.get("pos", jnp.arange(tokens.shape[1])[None])
    x, aux, _ = backbone(params, tokens, pos, cfg, plan, mode="train",
                         pos3=batch.get("pos3"), batch=batch)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    nll, z2 = _xent_chunked(x, w, batch["labels"], plan)
    z = 1e-4 * z2
    loss = nll + z + 1e-2 * aux
    return loss, {"nll": nll, "aux": aux, "zloss": z}


def prefill(params, batch, cfg: ArchConfig, plan: ShardingPlan,
            cache_len: int):
    """Build decode caches from a full prompt; returns (cache, last logits)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    assert S <= cache_len, "prompt longer than cache capacity"
    cache = init_cache(cfg, B, cache_len)
    cache["pos"] = jnp.int32(0)
    pos = batch.get("pos", jnp.arange(S)[None])
    x, _, new_cache = backbone(params, tokens, pos, cfg, plan, mode="prefill",
                               cache=cache, pos3=batch.get("pos3"),
                               batch=batch)
    logits = _unembed(params, x[:, -1:], cfg, plan)
    return new_cache, logits


def decode_step(params, cache, tokens, cfg: ArchConfig, plan: ShardingPlan,
                batch=None):
    """One token for every sequence in the batch. tokens (B, 1)."""
    pos = cache["pos"][None, None] + jnp.zeros(tokens.shape, jnp.int32)
    pos3 = jnp.broadcast_to(pos, (3,) + tuple(tokens.shape)) \
        if cfg.m_rope else None
    x, _, new_cache = backbone(params, tokens, pos, cfg, plan, mode="decode",
                               cache=cache, pos3=pos3, batch=batch)
    logits = _unembed(params, x, cfg, plan)
    return new_cache, logits
