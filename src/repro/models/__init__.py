"""LM substrate: layers, attention, MoE, SSM, model assembly."""
from . import attention, layers, model, moe, ssm
from .layers import ParamDef, init_params, param_shapestructs, param_specs
from .model import (backbone, cache_defs, decode_step, init_cache, layer_runs,
                    loss_fn, param_defs, prefill)

__all__ = ["ParamDef", "attention", "backbone", "cache_defs", "decode_step",
           "init_cache", "init_params", "layer_runs", "layers", "loss_fn",
           "model", "moe", "param_defs", "param_shapestructs", "param_specs",
           "prefill", "ssm"]
