"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch (EP).

Dispatch is MegaBlocks-style but static-shaped: assignments are sorted by
expert, each expert gets a ``capacity`` of slots, overflow tokens are dropped
(capacity_factor bounds the drop rate). The (E, C, d) dispatch tensor is
sharded over the ``exp`` logical axis, so GSPMD inserts the all-to-all from
batch-sharded tokens to expert-sharded slots — the EP communication pattern.

Router: softmax gating over top-k with load-balance + z auxiliary losses
(Switch/GShard style; deepseek-v3's bias-balanced sigmoid router is noted in
DESIGN.md as a simplification).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShardingPlan
from .layers import ParamDef, constrain


def moe_defs(cfg: ArchConfig, dt: str) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    defs = {
        "router": ParamDef((d, E), ("fsdp", None), dtype="float32"),
        "experts": {
            "w_gate": ParamDef((E, d, f), ("exp", "fsdp", None), dtype=dt),
            "w_up": ParamDef((E, d, f), ("exp", "fsdp", None), dtype=dt),
            "w_down": ParamDef((E, f, d), ("exp", None, "fsdp"), dtype=dt),
        },
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["shared"] = {
            "w_gate": ParamDef((d, fs), ("fsdp", "tp"), dtype=dt),
            "w_up": ParamDef((d, fs), ("fsdp", "tp"), dtype=dt),
            "w_down": ParamDef((fs, d), ("tp", "fsdp"), dtype=dt),
        }
    return defs


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(n_tokens * cfg.n_experts_per_tok * cfg.capacity_factor
            / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def _dispatch_group(xg, idx, gate, E: int, C: int):
    """Sort-based dispatch of ONE group. xg (T,d), idx/gate (T,k).

    Returns (dispatched (E*C, d), slot (T*k,), keep (T*k,), t_sorted)."""
    T, d = xg.shape
    k = idx.shape[1]
    expert = idx.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(expert, stable=True)
    e_sorted, t_sorted = expert[order], tok[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_sorted].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]
    keep = pos_in_e < C
    slot = jnp.where(keep, e_sorted * C + pos_in_e, E * C)      # dummy slot
    dispatched = jnp.zeros((E * C + 1, d), xg.dtype).at[slot].set(
        xg[t_sorted])[:E * C]
    return dispatched, slot, keep, t_sorted, order


def moe_apply(p, x, cfg: ArchConfig, plan: ShardingPlan):
    """x (B, S, d) -> (B, S, d), aux-loss scalar.

    GShard-style *grouped* dispatch: each batch row is a dispatch group with
    its own capacity C = ceil(S·k·cf / E), so the (G, E, C, d) dispatch
    tensor is sharded over BOTH the data axis (groups) and the expert axis —
    expert compute and all-to-all volume scale 1/(dp·ep) instead of 1/ep
    (the ungrouped scheme replicated expert work across the data axis; see
    EXPERIMENTS.md §Perf hillclimb A, 16× compute reduction on deepseek)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    C = capacity(S, cfg)
    xf = x.reshape(B, S, d)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                        # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # aux losses: load balance (Switch) + router z-loss (global over tokens)
    me = probs.mean((0, 1))                                     # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (B * S * k))
    aux = E * jnp.sum(me * ce)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = aux + 1e-3 * zloss

    # ---- per-group sort-based dispatch (vmapped over batch rows) ---------
    dispatched, slot, keep, t_sorted, order = jax.vmap(
        lambda xg, ig, gg: _dispatch_group(xg, ig, gg, E, C))(xf, idx, gate)
    h = dispatched.reshape(B, E, C, d)
    # reshard: groups stay on the data axis, experts move to the model axis
    # -> GSPMD inserts the (dp x ep) all-to-all here
    h = constrain(h, plan, ("batch", "exp", None, None))

    # ---- expert computation (grouped einsum, MXU-shaped) -----------------
    eg = p["experts"]
    hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", h, eg["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", h, eg["w_up"])
    out_e = jnp.einsum("gecf,efd->gecd", hidden, eg["w_down"])
    out_e = constrain(out_e, plan, ("batch", "exp", None, None))

    # ---- combine (back on the data axis) ----------------------------------
    flat = out_e.reshape(B, E * C, d)
    gathered = jax.vmap(lambda f, s, kp: jnp.where(
        kp[:, None], f[jnp.minimum(s, E * C - 1)], 0))(flat, slot, keep)
    g_sorted = jax.vmap(lambda g, o: g.reshape(-1)[o])(gate, order)
    y = jax.vmap(lambda ts, gv, gs: jnp.zeros((S, d), jnp.float32)
                 .at[ts].add(gv.astype(jnp.float32) * gs[:, None]))(
        t_sorted, gathered, g_sorted)

    if cfg.n_shared_experts:
        sh = p["shared"]
        xr = xf.reshape(B * S, d)
        y = y + (jax.nn.silu(xr @ sh["w_gate"]) * (xr @ sh["w_up"])
                 @ sh["w_down"]).astype(jnp.float32).reshape(B, S, d)

    y = y.astype(x.dtype).reshape(B, S, d)
    return constrain(y, plan, ("batch", None, "fsdp")), aux
