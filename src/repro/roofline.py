"""Loop-aware roofline analysis of compiled (SPMD-partitioned) HLO.

XLA's ``compiled.cost_analysis()`` visits every while body ONCE, so a model
scanned over L layers under-counts FLOPs/bytes/collectives by ~L× (verified
in this repo — see EXPERIMENTS.md §Roofline methodology). This module parses
the post-optimization HLO text instead:

  1. split into computations; per computation collect
       - dot/convolution FLOPs (2 · prod(out shape) · prod(contracting dims))
       - dot operand+output bytes (HBM-traffic proxy: weights/activations
         streamed per matmul — the dominant memory term for LM workloads)
       - collective bytes by op kind (per-device output-shape bytes)
  2. build the call graph (while bodies, calls, conditionals, fusions)
  3. walk from ENTRY multiplying by while trip counts (parsed from the loop
     condition's comparison constant; dynamic ``while_loop``s get 1× and are
     flagged)

Terms (TPU v5e per chip): compute = FLOPs / 197e12, memory = bytes / 819e9,
collective = bytes / 50e9 per link (all-reduce counted 2×: reduce-scatter +
all-gather phases). All quantities are per-device (the compiled module is the
per-device program), so terms are directly per-chip seconds.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12        # bf16 TFLOP/s per chip (TPU v5e)
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link
HBM_BYTES = 16e9           # HBM capacity per chip (TPU v5e)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_AR_FACTOR = 2.0           # ring AR = reduce-scatter + all-gather

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        # computation header: `%name (params...) -> type {` — params may nest
        # parens (tuple-typed), so match greedily up to `-> ... {`
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$",
                     line)
        if m and ("=" not in line.split("(")[0]):
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.strip() in ("}", "})"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _dims_list(attr: str, line: str) -> list[int]:
    m = re.search(attr + r"=\{([0-9,]*)\}", line)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: dict[str, int] = dataclasses.field(default_factory=dict)
    whiles: list[tuple[str, str, int | None]] = dataclasses.field(
        default_factory=list)
    calls: list[str] = dataclasses.field(default_factory=list)
    const_ints: list[int] = dataclasses.field(default_factory=list)
    dynamic_while: bool = False


def _analyze_computation(lines: list[str]) -> CompStats:
    st = CompStats()
    # symbol table: value name -> type string (ops define one value per line;
    # operands are printed as bare %names in optimized HLO)
    types: dict[str, str] = {}
    parsed: list[tuple[str, str, str, str]] = []  # (name, opcode, type, rhs)
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            for c in re.findall(r"constant\((\d+)\)", line):
                st.const_ints.append(int(c))
            continue
        name, rhs = m.group(1), m.group(2)
        for c in re.findall(r"constant\((\d+)\)", rhs):
            st.const_ints.append(int(c))
        op_m = re.search(r"(?:^|\)\s|\]\s|\}\s)\s*([a-z][a-z0-9\-]*)\(", rhs)
        opcode = op_m.group(1) if op_m else ""
        type_str = rhs.split(opcode + "(", 1)[0] if opcode else rhs
        types[name] = type_str
        parsed.append((name, opcode, type_str, rhs))

    def operand_names(rhs: str, opcode: str) -> list[str]:
        m = re.search(re.escape(opcode) + r"\(([^)]*)\)", rhs)
        if not m:
            return []
        return [o.strip().lstrip("%") for o in m.group(1).split(",")
                if o.strip()]

    def dims_of(name: str) -> list[int]:
        t = types.get(name)
        if not t:
            return []
        sm = _SHAPE_RE.search(t)
        if not sm:
            return []
        return [int(x) for x in sm.group(2).split(",") if x]

    for name, opcode, type_str, rhs in parsed:
        if opcode == "dot":
            out_elems = 1
            for d in dims_of(name):
                out_elems *= d
            ops = operand_names(rhs, "dot")
            contract = _dims_list("lhs_contracting_dims", rhs)
            c_elems = 1
            if ops:
                lhs_dims = dims_of(ops[0])
                for ci in contract:
                    if ci < len(lhs_dims):
                        c_elems *= lhs_dims[ci]
            st.dot_flops += 2.0 * out_elems * c_elems
            st.dot_bytes += _shape_bytes(type_str) + sum(
                _shape_bytes(types.get(o, "")) for o in ops[:2])
        elif opcode == "convolution":
            out_dims = dims_of(name)
            ops = operand_names(rhs, "convolution")
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            ker = 1
            for d in (dims_of(ops[1]) if len(ops) > 1 else []):
                ker *= d
            st.dot_flops += 2.0 * out_elems * max(ker, 1) / max(
                out_dims[-1] if out_dims else 1, 1)
            st.dot_bytes += _shape_bytes(type_str) + sum(
                _shape_bytes(types.get(o, "")) for o in ops[:2])
        elif opcode == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", rhs)
            bm = re.search(r"body=%?([\w.\-]+)", rhs)
            tm = re.search(r'known_trip_count[^}]*"n":"(\d+)"', rhs)
            if cm and bm:
                st.whiles.append((cm.group(1), bm.group(1),
                                  int(tm.group(1)) if tm else None))
        elif opcode in ("call", "fusion", "custom-call", "async-start"):
            for cal in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)", rhs):
                st.calls.append(cal)
        elif opcode == "conditional":
            for grp in re.findall(r"branch_computations=\{([^}]+)\}", rhs):
                for c in grp.split(","):
                    st.calls.append(c.strip().lstrip("%"))
            for cal in re.findall(
                    r"(?:true_computation|false_computation)=%?([\w.\-]+)",
                    rhs):
                st.calls.append(cal)
        else:
            base = None
            for cname in _COLLECTIVES:
                if opcode and opcode.startswith(cname):
                    base = cname
                    break
            if base and not (opcode or "").endswith("-done"):
                # wire-bytes basis per kind: AG counts received (output),
                # RS/A2A/permute count sent (operand), AR counts operand
                # (x2 applied later: ring AR = RS + AG phases)
                out_b = _shape_bytes(type_str)
                ops = operand_names(rhs, opcode or "")
                in_b = sum(_shape_bytes(types.get(o, "")) for o in ops)
                b = out_b if base == "all-gather" else max(in_b, out_b) \
                    if base == "all-reduce" else (in_b or out_b)
                st.coll_bytes[base] = st.coll_bytes.get(base, 0.0) + b
                st.coll_count[base] = st.coll_count.get(base, 0) + 1
    return st


def analyze_hlo(text: str) -> dict[str, Any]:
    """Loop-aware totals over the whole module (per-device quantities)."""
    comps = {name: _analyze_computation(lines)
             for name, lines in _split_computations(text).items()}
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:  # fall back: largest computation
        entry = max(comps, key=lambda k: len(comps[k].calls) + 1,
                    default=None)

    totals = dict(dot_flops=0.0, dot_bytes=0.0, coll_bytes={}, coll_count={},
                  dynamic_whiles=0, while_trips=[])

    def trip_count(cond_name: str) -> int | None:
        st = comps.get(cond_name)
        if st is None or not st.const_ints:
            return None
        return max(st.const_ints)

    seen_stack: list[str] = []

    def walk(name: str, mult: float):
        st = comps.get(name)
        if st is None or name in seen_stack:
            return
        seen_stack.append(name)
        totals["dot_flops"] += st.dot_flops * mult
        totals["dot_bytes"] += st.dot_bytes * mult
        for k, v in st.coll_bytes.items():
            totals["coll_bytes"][k] = totals["coll_bytes"].get(k, 0.0) + v * mult
        for k, v in st.coll_count.items():
            totals["coll_count"][k] = totals["coll_count"].get(k, 0) \
                + int(v * mult)
        for c in st.calls:
            walk(c, mult)
        for cond, body, trip in st.whiles:
            t = trip if trip is not None else trip_count(cond)
            if t is None:
                totals["dynamic_whiles"] += 1
                t = 1
            totals["while_trips"].append(t)
            walk(body, mult * t)
            walk(cond, mult * t)
        seen_stack.pop()

    if entry:
        walk(entry, 1.0)
    return totals


def roofline_terms(analysis: dict[str, Any], *, n_links: int = 4) -> dict:
    """Three per-chip roofline terms (seconds) from analyze_hlo output."""
    coll = analysis["coll_bytes"]
    coll_eff = sum(v * (_AR_FACTOR if k == "all-reduce" else 1.0)
                   for k, v in coll.items())
    compute_s = analysis["dot_flops"] / PEAK_FLOPS
    memory_s = analysis["dot_bytes"] / HBM_BW
    collective_s = coll_eff / (LINK_BW * n_links)
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s,
                 collective_bytes=coll_eff, flops=analysis["dot_flops"],
                 hbm_bytes=analysis["dot_bytes"])
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = terms["compute_s"] / total if total else 0.0
    return terms


def coloring_memory_projection(n_global: int, P: int, maxd: int, *,
                               maxd2: int = 0, ghost_frac: float = 0.5,
                               boundary_frac: float = 0.5,
                               batch: int = 1) -> dict:
    """Per-shard device bytes of the coloring layout under the id policy.

    Projects the ``PartitionedGraph.arrays()`` footprint for a graph of
    ``n_global`` vertices block-partitioned over ``P`` shards at max degree
    ``maxd`` (``maxd2`` adds the distance-2 ELL halo) — *without*
    allocating anything, so the int64 giant-graph regime (RMAT scale
    30+) can be sized on paper.  Id widths come from
    ``core.graph.id_policy``: the per-shard slot arrays (ELL neighbours,
    CSR columns, boundary/ghost tables) stay int32 at any global size —
    they index slots, not global ids — so promotion past the 2**31 vertex
    bound only widens the id-carrying arrays (``prio``/``gvid``) and the
    gather-index temporaries, and the projection makes that visible as
    ``promoted_extra_bytes``.

    ``ghost_frac``/``boundary_frac`` model the halo as a fraction of the
    local block (0.5 matches the repo's RMAT partitions at CPU scale;
    structured meshes sit far lower).  ``batch`` multiplies the working
    views (the batched pipeline holds one view per lane).  Returns the
    per-array byte dict plus totals and the HBM occupancy fraction.
    """
    import numpy as np                       # lazy: keep roofline import-light

    from repro.core.graph import id_policy

    n_local = -(-n_global // P)
    pol = id_policy(n_global, n_local, maxd, maxd2)
    n_ghost = int(n_local * ghost_frac)
    n_boundary = int(n_local * boundary_frac)
    n_slots = n_local + n_ghost + 1
    m_local = n_local * maxd
    id_b = pol.id_itemsize
    lanes = max(batch, 1)
    per = dict(
        nbr=n_local * maxd * 4,             # ELL neighbour slots: int32
        nbr2=n_local * maxd2 * 4,           # distance-2 ELL halo
        indices=m_local * 4,                # CSR column slots: int32
        edge_src=m_local * 4,
        indptr=(n_local + 1) * 4,
        prio=n_slots * id_b,                # global priorities: id-width
        gvid=n_slots * id_b,                # global-id map: id-width
        boundary=n_boundary * 4,
        ghost_tables=2 * n_ghost * 4,       # ghost_owner + ghost_slot
        degree_flags=n_local * 5,           # degree (int32) + is_internal
        views=n_slots * 4 * lanes,          # working color views per lane
    )
    total = sum(per.values())
    # what the same layout would cost if ids stayed int32 (the gap is the
    # whole price of the int64 promotion)
    extra = (n_slots * (id_b - 4)) * 2 if pol.promoted else 0
    return dict(
        n_global=int(n_global), P=int(P), n_local_max=int(n_local),
        maxd=int(maxd), maxd2=int(maxd2), batch=lanes,
        id_dtype=np.dtype(pol.id_dtype).name,
        ell_dtype=np.dtype(pol.ell_dtype).name,
        promoted=pol.promoted, promoted_extra_bytes=int(extra),
        per_shard_bytes=per, total_per_shard=int(total),
        hbm_fraction=total / HBM_BYTES, fits_hbm=total <= HBM_BYTES)


def model_flops(arch, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = arch.n_active_params() if arch.is_moe else arch.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens
