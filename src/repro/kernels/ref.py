"""Pure-jnp oracles for the color-selection kernels.

Semantics contract (shared with the Pallas kernels, asserted in tests):

- Colors are 1-based; color 0 and any padded/negative neighbour entry are
  ignored (bit 0 of the forbidden set is always considered taken).
- ``first_fit``: smallest color >= 1 not taken by a neighbour; if the whole
  [0, max_colors) range is taken, returns max_colors - 1.
- ``random_x``: uniform among the X smallest permissible colors (fewer if the
  free set is smaller), using ``rand % n_free``.
- ``conflict``: a vertex loses iff some neighbour has the same (nonzero)
  color and strictly higher priority.
- Inactive rows return 0 (first_fit/random_x) or False (conflict).
"""
from __future__ import annotations

import jax.numpy as jnp


def _forbidden(nbr_colors: jnp.ndarray, max_colors: int) -> jnp.ndarray:
    """(V, D) neighbour colors -> (V, max_colors) forbidden mask (col 0 set)."""
    v = nbr_colors.shape[0]
    c = jnp.clip(nbr_colors, 0, max_colors - 1)
    valid = (nbr_colors > 0) & (nbr_colors < max_colors)
    occ = jnp.zeros((v, max_colors), bool)
    rows = jnp.broadcast_to(jnp.arange(v)[:, None], c.shape)
    occ = occ.at[rows, c].max(valid)
    return occ.at[:, 0].set(True)


def first_fit(nbr_colors: jnp.ndarray, active: jnp.ndarray,
              max_colors: int) -> jnp.ndarray:
    """(V, D), (V,) -> (V,) first-fit colors (0 where inactive)."""
    occ = _forbidden(nbr_colors, max_colors)
    first = jnp.argmin(occ, axis=1).astype(jnp.int32)  # first False
    full = occ.all(axis=1)
    first = jnp.where(full, max_colors - 1, first)
    return jnp.where(active, first, 0).astype(jnp.int32)


def random_x(nbr_colors: jnp.ndarray, active: jnp.ndarray,
             rand_u32: jnp.ndarray, x: int, max_colors: int) -> jnp.ndarray:
    """(V, D), (V,), (V,) -> (V,) Random-X Fit colors (0 where inactive)."""
    occ = _forbidden(nbr_colors, max_colors)
    # positions of free colors, ascending; pad with max_colors-1 sentinel
    key = jnp.where(occ, jnp.int32(max_colors), jnp.arange(max_colors,
                                                           dtype=jnp.int32))
    cands = jnp.sort(key, axis=1)[:, :x]
    cands = jnp.minimum(cands, max_colors - 1).astype(jnp.int32)
    n_free = jnp.sum(cands < max_colors - 1, axis=1).astype(jnp.uint32)
    n_free = jnp.maximum(n_free, jnp.uint32(1))
    idx = (rand_u32 % n_free).astype(jnp.int32)
    pick = jnp.take_along_axis(cands, idx[:, None], axis=1)[:, 0]
    return jnp.where(active, pick, 0).astype(jnp.int32)


def conflict(my_color: jnp.ndarray, my_prio: jnp.ndarray,
             nbr_colors: jnp.ndarray, nbr_prio: jnp.ndarray,
             active: jnp.ndarray) -> jnp.ndarray:
    """(V,), (V,), (V, D), (V, D), (V,) -> (V,) bool 'must recolor'."""
    same = (nbr_colors == my_color[:, None]) & (my_color[:, None] > 0)
    lose = same & (nbr_prio > my_prio[:, None])
    return lose.any(axis=1) & active
