"""Jitted public wrappers around the Pallas color-selection kernels.

On CPU (this container) the kernels run with ``interpret=True`` — the kernel
body executes unmodified in Python, which validates the TPU code path; on a
real TPU backend pass ``interpret=False`` (default chosen by backend).

The wrappers pad the vertex dimension to the kernel tile and accept 0/negative
neighbour-color padding (ignored per the semantics contract in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .firstfit import TILE_V, color_select_pallas, conflict_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_v(x, v_pad, fill=0):
    pad = [(0, v_pad - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("max_colors", "x", "interpret"))
def color_select(nbr_colors, active, rand_u32, *, max_colors: int, x: int = 0,
                 interpret: bool | None = None):
    """First Fit (x=0) / Random-X Fit (x>0) over a dense neighbour tile.

    nbr_colors (V, MAXD) int32; active (V,) bool; rand_u32 (V,) uint32.
    """
    if interpret is None:
        interpret = _default_interpret()
    v = nbr_colors.shape[0]
    v_pad = -(-v // TILE_V) * TILE_V
    out = color_select_pallas(
        _pad_v(nbr_colors, v_pad), _pad_v(active, v_pad),
        _pad_v(rand_u32, v_pad), max_colors=max_colors, x=x,
        interpret=interpret)
    return out[:v]


@functools.partial(jax.jit, static_argnames=("interpret",))
def conflict(my_color, my_prio, nbr_colors, nbr_prio, active, *,
             interpret: bool | None = None):
    """Conflict detection over a dense neighbour tile. Returns (V,) bool."""
    if interpret is None:
        interpret = _default_interpret()
    v = nbr_colors.shape[0]
    v_pad = -(-v // TILE_V) * TILE_V
    out = conflict_pallas(
        _pad_v(my_color, v_pad), _pad_v(my_prio, v_pad, fill=-1),
        _pad_v(nbr_colors, v_pad), _pad_v(nbr_prio, v_pad, fill=-1),
        _pad_v(active, v_pad), interpret=interpret)
    return out[:v].astype(bool)
