"""Jitted public wrappers around the color-selection kernels.

``select_colors`` is the ONE entry point the distributed hot paths
(`core.recolor`, `core.speculative`) route through.  It takes a padded
neighbour-color tile (the gather of an ELL row block, see DESIGN.md §3) and
picks a color per row with a ``backend`` switch:

  backend="pallas" — the Pallas TPU tile kernels in ``firstfit.py``.  On a
                     non-TPU backend the kernels run with ``interpret=True``,
                     which executes the kernel body unmodified in Python and
                     validates the TPU code path.
  backend="xla"    — the *same* bitset math (``select_from_words``) applied to
                     the whole tile as ordinary vectorized XLA ops.  This is
                     the fast CPU/sim path and the semantics oracle for the
                     Pallas path; equivalence is pinned by tests.
  backend="auto"   — "pallas" on TPU, "xla" elsewhere (the default the
                     drivers use, so sim runs stay fast and TPU runs hit the
                     kernels without any config change).

Strategies: "first_fit", "staggered" (per-row start offset, wraps to plain
first fit when exhausted) and "random_x" (uniform among the X smallest free
colors).  "least_used" is inherently sequential (it chases a running usage
histogram) and stays on the scalar path in ``core.speculative``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .firstfit import (TILE_V, _forbidden_words, color_select_pallas,
                       color_select_pallas_d2, conflict_pallas,
                       conflict_pallas_d2, select_from_words)

# Strategy names, mirroring repro.core.selection (string-equal; duplicated
# here so kernels never import core and the layering stays one-way).
FIRST_FIT = "first_fit"
STAGGERED = "staggered"
RANDOM_X = "random_x"
SELECTIONS = (FIRST_FIT, STAGGERED, RANDOM_X)

BACKENDS = ("auto", "xla", "pallas")


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}, want one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _pad_v(x, v_pad, fill=0):
    pad = [(0, v_pad - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


def _flat_call(fn, lead, v, rows: dict, tiles: dict, **kw):
    """Flatten leading batch dims onto the row axis and call the 2-D entry.

    ``rows`` — (…, V) operands (None and scalars broadcast); ``tiles`` —
    (…, V, D) operands.  Rows of a (B, V, D) multi-graph tile land
    contiguously on the flat row axis: the XLA twin vectorizes the flat
    tile directly, the Pallas grid just tiles the extra rows (a per-graph
    grid axis) — one kernel launch per batch.  Returns (…, V).
    """
    flat = lambda a: jnp.reshape(
        jnp.broadcast_to(jnp.asarray(a), lead + (v,)), (-1,))
    args = {k: (None if a is None else flat(a)) for k, a in rows.items()}
    args.update({k: jnp.reshape(jnp.asarray(a),
                                (-1,) + jnp.shape(a)[-1:])
                 for k, a in tiles.items()})
    return fn(**args, **kw).reshape(lead + (v,))


def select_colors(nbr_colors, active, rand_u32=None, *, max_colors: int,
                  selection: str = FIRST_FIT, x: int = 10, offset=None,
                  backend: str = "auto", interpret: bool | None = None):
    """Tile-parallel color selection over a padded neighbour tile.

    nbr_colors (V, MAXD) int32 (0 / negative / >=max_colors entries ignored);
    active (V,) bool-ish; rand_u32 (V,) uint32 (random_x only); offset scalar
    or (V,) int32 (staggered only).  Returns (V,) int32, 0 where inactive.
    Traceable — call it from inside jitted SPMD code.

    Leading batch dims are accepted on every per-row operand — e.g. a
    ``(B, V, MAXD)`` multi-graph tile with ``(B, V)`` masks returns
    ``(B, V)`` colors.  Rows are flattened onto the row axis: the XLA twin
    vectorizes the flat tile directly, and the Pallas grid simply tiles the
    extra rows (a per-graph grid axis) — one kernel launch per batch.
    """
    if selection not in SELECTIONS:
        raise ValueError(
            f"unknown selection {selection!r}, want one of {SELECTIONS}")
    assert max_colors % 32 == 0
    backend = resolve_backend(backend)
    nbr_colors = jnp.asarray(nbr_colors)
    if nbr_colors.ndim > 2:
        return _flat_call(
            select_colors, nbr_colors.shape[:-2], nbr_colors.shape[-2],
            rows=dict(active=active, rand_u32=rand_u32, offset=offset),
            tiles=dict(nbr_colors=nbr_colors), max_colors=max_colors,
            selection=selection, x=x, backend=backend, interpret=interpret)
    v = nbr_colors.shape[0]
    staggered = selection == STAGGERED
    x_eff = x if selection == RANDOM_X else 0
    if rand_u32 is None:
        rand_u32 = jnp.zeros((v,), jnp.uint32)
    if offset is None:
        offset = jnp.zeros((v,), jnp.int32)
    else:
        offset = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (v,))
    active = jnp.asarray(active)

    if backend == "xla":
        words = _forbidden_words(nbr_colors, max_colors // 32)
        color = select_from_words(words, rand_u32, offset, x=x_eff,
                                  staggered=staggered)
        return jnp.where(active != 0, color, 0).astype(jnp.int32)

    if interpret is None:
        interpret = _default_interpret()
    v_pad = -(-v // TILE_V) * TILE_V
    out = color_select_pallas(
        _pad_v(nbr_colors, v_pad), _pad_v(active, v_pad),
        _pad_v(rand_u32, v_pad), _pad_v(offset, v_pad),
        max_colors=max_colors, x=x_eff, staggered=staggered,
        interpret=interpret)
    return out[:v]


def select_colors_d2(nbr_colors, nbr2_colors, active, rand_u32=None, *,
                     max_colors: int, selection: str = FIRST_FIT, x: int = 10,
                     offset=None, backend: str = "auto",
                     interpret: bool | None = None):
    """Distance-2 color selection over two padded neighbour tiles.

    Same contract as ``select_colors`` (leading batch dims included) plus
    ``nbr2_colors`` (V, MAXD2) int32 — the strict two-hop neighbour colors.
    Both backends OR the one-hop and two-hop forbidden bitsets before
    selecting, so a chosen color differs from every color within graph
    distance 2.
    """
    if selection not in SELECTIONS:
        raise ValueError(
            f"unknown selection {selection!r}, want one of {SELECTIONS}")
    assert max_colors % 32 == 0
    backend = resolve_backend(backend)
    nbr_colors = jnp.asarray(nbr_colors)
    nbr2_colors = jnp.asarray(nbr2_colors)
    if nbr_colors.ndim > 2:
        return _flat_call(
            select_colors_d2, nbr_colors.shape[:-2], nbr_colors.shape[-2],
            rows=dict(active=active, rand_u32=rand_u32, offset=offset),
            tiles=dict(nbr_colors=nbr_colors, nbr2_colors=nbr2_colors),
            max_colors=max_colors, selection=selection, x=x,
            backend=backend, interpret=interpret)
    v = nbr_colors.shape[0]
    staggered = selection == STAGGERED
    x_eff = x if selection == RANDOM_X else 0
    if rand_u32 is None:
        rand_u32 = jnp.zeros((v,), jnp.uint32)
    if offset is None:
        offset = jnp.zeros((v,), jnp.int32)
    else:
        offset = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (v,))
    active = jnp.asarray(active)

    if backend == "xla":
        words = (_forbidden_words(nbr_colors, max_colors // 32)
                 | _forbidden_words(nbr2_colors, max_colors // 32))
        color = select_from_words(words, rand_u32, offset, x=x_eff,
                                  staggered=staggered)
        return jnp.where(active != 0, color, 0).astype(jnp.int32)

    if interpret is None:
        interpret = _default_interpret()
    v_pad = -(-v // TILE_V) * TILE_V
    out = color_select_pallas_d2(
        _pad_v(nbr_colors, v_pad), _pad_v(nbr2_colors, v_pad),
        _pad_v(active, v_pad), _pad_v(rand_u32, v_pad), _pad_v(offset, v_pad),
        max_colors=max_colors, x=x_eff, staggered=staggered,
        interpret=interpret)
    return out[:v]


def detect_conflicts(my_color, my_prio, nbr_colors, nbr_prio, active, *,
                     backend: str = "auto", interpret: bool | None = None):
    """Tile-parallel conflict detection: row loses iff a neighbour holds the
    same (nonzero) color with strictly higher priority.  Returns (V,) bool.
    Traceable; same backend contract as ``select_colors``, leading batch
    dims accepted on every operand.
    """
    backend = resolve_backend(backend)
    my_color = jnp.asarray(my_color)
    nbr_colors = jnp.asarray(nbr_colors)
    if nbr_colors.ndim > 2:
        return _flat_call(
            detect_conflicts, nbr_colors.shape[:-2], nbr_colors.shape[-2],
            rows=dict(my_color=my_color, my_prio=my_prio, active=active),
            tiles=dict(nbr_colors=nbr_colors, nbr_prio=nbr_prio),
            backend=backend, interpret=interpret)
    active = jnp.asarray(active)
    if backend == "xla":
        same = (nbr_colors == my_color[:, None]) & (my_color[:, None] > 0)
        lose = (same & (nbr_prio > my_prio[:, None])).any(axis=1)
        return lose & (active != 0)
    if interpret is None:
        interpret = _default_interpret()
    v = my_color.shape[0]
    v_pad = -(-v // TILE_V) * TILE_V
    out = conflict_pallas(
        _pad_v(my_color, v_pad), _pad_v(my_prio, v_pad, fill=-1),
        _pad_v(nbr_colors, v_pad), _pad_v(nbr_prio, v_pad, fill=-1),
        _pad_v(active, v_pad), interpret=interpret)
    return out[:v].astype(bool)


def detect_conflicts_d2(my_color, my_prio, nbr_colors, nbr_prio, nbr2_colors,
                        nbr2_prio, active, *, backend: str = "auto",
                        interpret: bool | None = None):
    """Distance-2 conflict detection: row loses iff any neighbour at graph
    distance <= 2 holds the same (nonzero) color with strictly higher
    priority. Returns (V,) bool; same backend contract as ``select_colors``,
    leading batch dims accepted on every operand.
    """
    backend = resolve_backend(backend)
    my_color = jnp.asarray(my_color)
    nbr_colors = jnp.asarray(nbr_colors)
    if nbr_colors.ndim > 2:
        return _flat_call(
            detect_conflicts_d2, nbr_colors.shape[:-2], nbr_colors.shape[-2],
            rows=dict(my_color=my_color, my_prio=my_prio, active=active),
            tiles=dict(nbr_colors=nbr_colors, nbr_prio=nbr_prio,
                       nbr2_colors=nbr2_colors, nbr2_prio=nbr2_prio),
            backend=backend, interpret=interpret)
    active = jnp.asarray(active)
    if backend == "xla":
        myc, myp = my_color[:, None], jnp.asarray(my_prio)[:, None]
        lose = (((nbr_colors == myc) & (myc > 0) & (nbr_prio > myp))
                .any(axis=1)
                | ((nbr2_colors == myc) & (myc > 0) & (nbr2_prio > myp))
                .any(axis=1))
        return lose & (active != 0)
    if interpret is None:
        interpret = _default_interpret()
    v = my_color.shape[0]
    v_pad = -(-v // TILE_V) * TILE_V
    out = conflict_pallas_d2(
        _pad_v(my_color, v_pad), _pad_v(my_prio, v_pad, fill=-1),
        _pad_v(nbr_colors, v_pad), _pad_v(nbr_prio, v_pad, fill=-1),
        _pad_v(nbr2_colors, v_pad), _pad_v(nbr2_prio, v_pad, fill=-1),
        _pad_v(active, v_pad), interpret=interpret)
    return out[:v].astype(bool)


@functools.partial(jax.jit, static_argnames=("max_colors", "x", "interpret"))
def color_select(nbr_colors, active, rand_u32, *, max_colors: int, x: int = 0,
                 interpret: bool | None = None):
    """First Fit (x=0) / Random-X Fit (x>0) via the Pallas path (jitted)."""
    return select_colors(nbr_colors, active, rand_u32, max_colors=max_colors,
                         selection=RANDOM_X if x else FIRST_FIT, x=x,
                         backend="pallas", interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def conflict(my_color, my_prio, nbr_colors, nbr_prio, active, *,
             interpret: bool | None = None):
    """Conflict detection over a dense neighbour tile. Returns (V,) bool."""
    if interpret is None:
        interpret = _default_interpret()
    v = nbr_colors.shape[0]
    v_pad = -(-v // TILE_V) * TILE_V
    out = conflict_pallas(
        _pad_v(my_color, v_pad), _pad_v(my_prio, v_pad, fill=-1),
        _pad_v(nbr_colors, v_pad), _pad_v(nbr_prio, v_pad, fill=-1),
        _pad_v(active, v_pad), interpret=interpret)
    return out[:v].astype(bool)
