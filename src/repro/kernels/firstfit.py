"""Pallas TPU kernels for the paper's per-vertex hot loop (color selection).

The recoloring step's compute kernel is: for a tile of vertices, build the
forbidden-color set from neighbour colors and pick a color (First Fit,
Random-X Fit §3.2, or Staggered First Fit via a per-row offset operand).
On TPU we tile vertices onto VPU lanes and keep the
forbidden set as a uint32 *bitset* — ``max_colors / 32`` words per vertex —
resident in VMEM/VREGs:

  HBM  : neighbour-color tile (TILE_V, MAXD) int32, streamed per grid step
  VMEM : (TILE_V, MAXD) input block + (TILE_V, W) bitset working set
  VPU  : MAXD-step reduction of one-hot word ORs; find-first-zero via
         bit tricks + population_count (no scalar loops over vertices)

This is the TPU-native rethink of the paper's per-vertex sequential scan:
the sequential dependency *within* a color class does not exist (the class is
an independent set), so the whole tile colors in parallel — exactly why
synchronous recoloring suits wide SIMD hardware.

Grid: (ceil(V / TILE_V),). MAXD is the (padded) max degree of the tile's
vertices. Typical VMEM use at TILE_V=256, MAXD=128, W=32: ~160 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TILE_V = 256  # vertices per grid step; multiple of 8 (f32 sublane) x 128 lanes

_U1 = np.uint32(1)
_FULL = np.uint32(0xFFFFFFFF)


def _forbidden_words(nbr_ref, n_words: int) -> jnp.ndarray:
    """(TILE_V, MAXD) neighbour colors -> (TILE_V, W) forbidden bitset."""
    tile_v, maxd = nbr_ref.shape
    words = jnp.zeros((tile_v, n_words), jnp.uint32).at[:, 0].set(_U1)
    warange = jnp.arange(n_words, dtype=jnp.int32)[None, :]

    def body(d, words):
        c = nbr_ref[:, d]                                   # (TILE_V,)
        ok = (c > 0) & (c < n_words * 32)
        cc = jnp.clip(c, 0, n_words * 32 - 1)
        w = (cc >> 5)[:, None]                              # (TILE_V, 1)
        bit = (_U1 << (cc & 31).astype(jnp.uint32))[:, None]
        hit = (warange == w) & ok[:, None]
        return words | jnp.where(hit, bit, jnp.uint32(0))

    return jax.lax.fori_loop(0, maxd, body, words)


def _find_first_zero(words: jnp.ndarray) -> jnp.ndarray:
    """(TILE_V, W) bitset -> (TILE_V,) lowest zero bit below the sentinel.

    Bit ``32W-1`` is reserved as a saturation sentinel (never reported free),
    so a result of ``32W-1`` unambiguously means "no permissible color" —
    mirrors ``core.selection.find_first_zero``.
    """
    tile_v, n_words = words.shape
    top = jnp.where(jnp.arange(n_words, dtype=jnp.int32)[None, :]
                    == n_words - 1, ~jnp.uint32(0x7FFFFFFF), jnp.uint32(0))
    free = ~(words | top)
    has = free != jnp.uint32(0)
    iota = jnp.broadcast_to(jnp.arange(n_words, dtype=jnp.int32)[None, :],
                            (tile_v, n_words))
    widx = jnp.min(jnp.where(has, iota, n_words), axis=1)
    widx_c = jnp.minimum(widx, n_words - 1)
    word = jnp.take_along_axis(free, widx_c[:, None], axis=1)[:, 0]
    lsb = word & (~word + _U1)
    bit = jax.lax.population_count(lsb - _U1).astype(jnp.int32)
    out = widx_c * 32 + bit
    return jnp.where(widx >= n_words, n_words * 32 - 1, out)


def _set_bits(words: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Set per-row bit `c` in the (TILE_V, W) bitset."""
    n_words = words.shape[1]
    warange = jnp.arange(n_words, dtype=jnp.int32)[None, :]
    w = (c >> 5)[:, None]
    bit = (_U1 << (c & 31).astype(jnp.uint32))[:, None]
    return words | jnp.where(warange == w, bit, jnp.uint32(0))


def _mask_below_rows(words: jnp.ndarray, off: jnp.ndarray) -> jnp.ndarray:
    """Copy of the (V, W) bitset with all bits < off[v] additionally set."""
    n_words = words.shape[1]
    warange = jnp.arange(n_words, dtype=jnp.int32)[None, :]
    widx = (off >> 5)[:, None]
    rem = (off & 31).astype(jnp.uint32)[:, None]
    partial = jnp.where(warange == widx, (_U1 << rem) - _U1, jnp.uint32(0))
    return words | jnp.where(warange < widx, _FULL, jnp.uint32(0)) | partial


def select_from_words(words, rand_u32, offset, *, x: int, staggered: bool):
    """(V, W) forbidden bitset -> (V,) colors.

    The one tile-parallel selection routine: First Fit (x=0), Random-X Fit
    (x>0, uniform among the X smallest free colors via ``rand_u32``) and
    Staggered First Fit (first fit from per-row ``offset``, wrapping to plain
    first fit when exhausted). Shared verbatim by the Pallas tile kernel and
    the vectorized XLA backend in ``kernels.ops`` — they differ only in how
    tiles reach the VPU, never in the math.
    """
    if staggered:
        c = _find_first_zero(_mask_below_rows(words, offset))
        full = c >= words.shape[1] * 32 - 1
        return jnp.where(full, _find_first_zero(words), c)
    if x == 0:
        return _find_first_zero(words)
    mc = words.shape[1] * 32
    tile_v = words.shape[0]
    cands = jnp.full((tile_v, x), mc - 1, jnp.int32)

    def body(k, carry):
        words, cands = carry
        c = _find_first_zero(words)
        cands = cands.at[:, k].set(c)
        return _set_bits(words, c), cands

    _, cands = jax.lax.fori_loop(0, x, body, (words, cands))
    n_free = jnp.sum((cands < mc - 1).astype(jnp.uint32), axis=1)
    n_free = jnp.maximum(n_free, _U1)
    idx = (rand_u32 % n_free).astype(jnp.int32)
    return jnp.take_along_axis(cands, idx[:, None], axis=1)[:, 0]


def _select_kernel(nbr_ref, active_ref, rand_ref, off_ref, out_ref, *,
                   n_words: int, x: int, staggered: bool):
    """x == 0 -> First Fit; x > 0 -> Random-X Fit; staggered -> offset FF."""
    words = _forbidden_words(nbr_ref[...], n_words)
    color = select_from_words(words, rand_ref[...], off_ref[...], x=x,
                              staggered=staggered)
    out_ref[...] = jnp.where(active_ref[...] != 0, color, 0).astype(jnp.int32)


def _select_kernel_d2(nbr_ref, nbr2_ref, active_ref, rand_ref, off_ref,
                      out_ref, *, n_words: int, x: int, staggered: bool):
    """Distance-2 selection: OR the 1-hop and 2-hop forbidden bitsets."""
    words = (_forbidden_words(nbr_ref[...], n_words)
             | _forbidden_words(nbr2_ref[...], n_words))
    color = select_from_words(words, rand_ref[...], off_ref[...], x=x,
                              staggered=staggered)
    out_ref[...] = jnp.where(active_ref[...] != 0, color, 0).astype(jnp.int32)


def _lose_against(myc, myp, nbrc, nbrp):
    same = (nbrc == myc) & (myc > 0)
    return (same & (nbrp > myp)).any(axis=1)


def _conflict_kernel(myc_ref, myp_ref, nbrc_ref, nbrp_ref, active_ref,
                     out_ref):
    lose = _lose_against(myc_ref[...][:, None], myp_ref[...][:, None],
                         nbrc_ref[...], nbrp_ref[...])
    out_ref[...] = (lose & (active_ref[...] != 0)).astype(jnp.int32)


def _conflict_kernel_d2(myc_ref, myp_ref, nbrc_ref, nbrp_ref, nbr2c_ref,
                        nbr2p_ref, active_ref, out_ref):
    """Distance-2 conflicts: lose against any 1-hop OR 2-hop neighbour."""
    myc = myc_ref[...][:, None]
    myp = myp_ref[...][:, None]
    lose = (_lose_against(myc, myp, nbrc_ref[...], nbrp_ref[...])
            | _lose_against(myc, myp, nbr2c_ref[...], nbr2p_ref[...]))
    out_ref[...] = (lose & (active_ref[...] != 0)).astype(jnp.int32)


def color_select_pallas(nbr_colors, active, rand_u32, offset=None, *,
                        max_colors: int, x: int = 0, staggered: bool = False,
                        interpret: bool = False):
    """Tile-parallel color selection. V must be a multiple of TILE_V.

    nbr_colors (V, MAXD) int32, active (V,) int32/bool, rand_u32 (V,) uint32,
    offset (V,) int32 (staggered start color; ignored unless ``staggered``).
    Returns (V,) int32 chosen colors (0 where inactive).
    """
    assert max_colors % 32 == 0
    v, maxd = nbr_colors.shape
    assert v % TILE_V == 0, f"V={v} not a multiple of {TILE_V}"
    if offset is None:
        offset = jnp.zeros((v,), jnp.int32)
    n_words = max_colors // 32
    grid = (v // TILE_V,)
    kernel = functools.partial(_select_kernel, n_words=n_words, x=x,
                               staggered=staggered)
    vec = pl.BlockSpec((TILE_V,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_V, maxd), lambda i: (i, 0)),
            vec, vec, vec,
        ],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((v,), jnp.int32),
        interpret=interpret,
    )(nbr_colors, active.astype(jnp.int32), rand_u32,
      offset.astype(jnp.int32))


def color_select_pallas_d2(nbr_colors, nbr2_colors, active, rand_u32,
                          offset=None, *, max_colors: int, x: int = 0,
                          staggered: bool = False, interpret: bool = False):
    """Distance-2 tile-parallel selection. V must be a multiple of TILE_V.

    Same contract as ``color_select_pallas`` with a second padded neighbour
    tile ``nbr2_colors`` (V, MAXD2) — the strict two-hop colors; the kernel
    ORs both forbidden bitsets before the find-first-zero.
    """
    assert max_colors % 32 == 0
    v, maxd = nbr_colors.shape
    _, maxd2 = nbr2_colors.shape
    assert v % TILE_V == 0, f"V={v} not a multiple of {TILE_V}"
    if offset is None:
        offset = jnp.zeros((v,), jnp.int32)
    n_words = max_colors // 32
    grid = (v // TILE_V,)
    kernel = functools.partial(_select_kernel_d2, n_words=n_words, x=x,
                               staggered=staggered)
    vec = pl.BlockSpec((TILE_V,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_V, maxd), lambda i: (i, 0)),
            pl.BlockSpec((TILE_V, maxd2), lambda i: (i, 0)),
            vec, vec, vec,
        ],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((v,), jnp.int32),
        interpret=interpret,
    )(nbr_colors, nbr2_colors, active.astype(jnp.int32), rand_u32,
      offset.astype(jnp.int32))


def conflict_pallas(my_color, my_prio, nbr_colors, nbr_prio, active, *,
                    interpret: bool = False):
    """Tile-parallel conflict detection. Returns (V,) int32 (1 = recolor)."""
    v, maxd = nbr_colors.shape
    assert v % TILE_V == 0, f"V={v} not a multiple of {TILE_V}"
    grid = (v // TILE_V,)
    vec = pl.BlockSpec((TILE_V,), lambda i: (i,))
    mat = pl.BlockSpec((TILE_V, maxd), lambda i: (i, 0))
    return pl.pallas_call(
        _conflict_kernel,
        grid=grid,
        in_specs=[vec, vec, mat, mat, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((v,), jnp.int32),
        interpret=interpret,
    )(my_color, my_prio, nbr_colors, nbr_prio, active.astype(jnp.int32))


def conflict_pallas_d2(my_color, my_prio, nbr_colors, nbr_prio, nbr2_colors,
                       nbr2_prio, active, *, interpret: bool = False):
    """Distance-2 conflict detection over both neighbour tiles."""
    v, maxd = nbr_colors.shape
    _, maxd2 = nbr2_colors.shape
    assert v % TILE_V == 0, f"V={v} not a multiple of {TILE_V}"
    grid = (v // TILE_V,)
    vec = pl.BlockSpec((TILE_V,), lambda i: (i,))
    mat = pl.BlockSpec((TILE_V, maxd), lambda i: (i, 0))
    mat2 = pl.BlockSpec((TILE_V, maxd2), lambda i: (i, 0))
    return pl.pallas_call(
        _conflict_kernel_d2,
        grid=grid,
        in_specs=[vec, vec, mat, mat, mat2, mat2, vec],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((v,), jnp.int32),
        interpret=interpret,
    )(my_color, my_prio, nbr_colors, nbr_prio, nbr2_colors, nbr2_prio,
      active.astype(jnp.int32))
