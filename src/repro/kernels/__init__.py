"""Pallas TPU kernels for the coloring hot spots (+ jnp oracles in ref.py)."""
from . import ops, ref
from .firstfit import TILE_V, color_select_pallas, conflict_pallas

__all__ = ["TILE_V", "color_select_pallas", "conflict_pallas", "ops", "ref"]
