"""Host-side graph substrate: global CSR + distributed partitioning.

The distributed layout mirrors the paper (§2.2): each processor owns a
contiguous block of vertices (block partitioning, as the paper uses for the
RMAT graphs); for every cross-partition edge both endpoints' processors know
the edge. Vertices whose neighbours are all local are *internal*; the rest are
*boundary*. Remote neighbours appear locally as *ghost* slots.

Device layout (per processor p, padded to common maxima so the arrays stack
into a leading-P axis for `SimComm`/`shard_map`):

  view slots  = [0, n_local_max)                local vertices
              | [n_local_max, n_local_max+g)    ghosts (stale remote colors)
              | sentinel slot (always color 0)  at index n_slots-1

  ``indices`` holds slot ids; padded entries point at the sentinel.
  ``nbr`` is the same adjacency in padded-neighbor (ELL) form: one
  ``(n_local_max, maxd)`` row of slot ids per vertex, padded with the sentinel
  slot, so a tile of vertices gathers its whole neighbourhood with one
  ``view[nbr[rows]]`` — the layout the bitset selection kernels consume
  (DESIGN.md §3). ELL trades ``n_local_max * maxd`` storage for gather-only
  (scatter-free) hot loops; ``maxd`` is the max degree over all processors.
  ``boundary`` lists local boundary slots; only boundary colors ever travel.
  Under the broadcast scheme the exchange payload of processor p is
  ``view[boundary]``: ghost g of processor p is owned by ``ghost_owner[g]``
  and lives at position ``ghost_slot[g]`` of that owner's payload, so after
  an all-gather of payloads P×max_b, ghosts refresh with one gather.
  Under the sparse scheme (``CommPlan``, built by ``build_comm_plan``) each
  processor instead ships per-destination send lists over a static
  ``ppermute`` round schedule — the faithful analogue of the paper's
  neighbour-to-neighbour boundary messages, with wire bytes that track the
  realized cross-edge structure instead of P (DESIGN.md §2).

  ``partition_graph(..., halo=2)`` widens everything to the *two-hop halo*
  for distance-2 coloring (DESIGN.md §5): ghosts cover every remote vertex
  within two hops, ``nbr2`` holds the strict two-hop ELL, and
  ``boundary``/``is_internal`` mean "read by some other shard".  The comm
  plan is halo-agnostic — depth-2 ghosts are ordinary ghost-table entries.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Global symmetric CSR graph (host, numpy)."""

    n: int
    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (2m,) int32 (int64 past the 2**31 id bound)

    @property
    def m_directed(self) -> int:
        return int(self.indices.shape[0])

    @property
    def m(self) -> int:
        return self.m_directed // 2

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max(initial=0))

    def validate_coloring(self, colors: np.ndarray) -> bool:
        """True iff `colors` (1-based, 0=uncolored disallowed) is proper."""
        if (colors <= 0).any():
            return False
        src = np.repeat(np.arange(self.n), self.degrees)
        return bool((colors[src] != colors[self.indices]).all())

    def num_colors(self, colors: np.ndarray) -> int:
        """Distinct positive colors in use (not the max id — recoloring can
        empty classes below the maximum, leaving gaps in the id range)."""
        c = np.unique(np.asarray(colors))
        return int((c > 0).sum())


#: per-shard slot index arrays (slots, ELL neighbours) are int32 below this.
INT32_LIMIT = 2**31
#: hard ceiling of the id layout — int64 ids cannot represent past this.
INT64_LIMIT = 2**63


@dataclasses.dataclass(frozen=True)
class IdPolicy:
    """The single id-width decision point (DESIGN.md §10).

    Two independent hazards, each with its own dtype verdict:

    - **global ids** (``gvid``, ``prio``, CSR ``indices``, the RMAT edge
      packing): int32 while ``n_global < 2**31``, int64 past it;
    - **the flattened ELL index** ``v * maxd + k`` the selection kernels
      compute per shard: int32 while ``n_local_max * max(maxd, maxd2)``
      stays under 2**31.  *Per-shard* slot ids (``nbr``, ``indices`` slot
      entries, ``boundary``) are bounded by ``n_slots`` and stay int32
      regardless — only the flat-index arithmetic widens.

    ``promoted`` is true when either verdict is int64 — the giant-graph
    regime the int32 guard used to reject outright.  ``id_policy`` is the
    only place that compares against ``INT32_LIMIT``; everything else
    (``partition_graph``, ``rmat``, roofline projections) consumes the
    policy's dtypes.
    """

    n_global: int
    ell: int                 # n_local_max * max(maxd, maxd2, 1)
    id_dtype: object         # numpy dtype for global vertex ids
    ell_dtype: object        # numpy dtype for flattened ELL indices

    @property
    def promoted(self) -> bool:
        return (np.dtype(self.id_dtype) == np.int64
                or np.dtype(self.ell_dtype) == np.int64)

    @property
    def id_itemsize(self) -> int:
        return np.dtype(self.id_dtype).itemsize


def id_policy(n_global: int, n_local_max: int, maxd: int, maxd2: int = 0,
              *, allow_int64: bool = True) -> IdPolicy:
    """Decide the id widths for a (partitioned) graph's device layout.

    Pure shape arithmetic — callable (and testable) without allocating the
    arrays it governs.  Under ``allow_int64=True`` (the default) crossing
    either int32 bound *promotes* the affected dtype to int64 instead of
    raising; ``allow_int64=False`` reproduces the historical hard guard
    (``check_int32_limits``).  int64 itself overflowing is always an error.
    """
    ell = n_local_max * max(maxd, maxd2, 1)
    if n_global >= INT64_LIMIT or ell >= INT64_LIMIT:
        raise ValueError(
            f"graph exceeds the int64 id range: n_global={n_global}, "
            f"n_local_max * maxd = {ell} (>= {INT64_LIMIT})")
    if not allow_int64:
        if n_global >= INT32_LIMIT:
            raise ValueError(
                f"graph has {n_global} vertices but device vertex ids are "
                f"int32 (< {INT32_LIMIT}); this exceeds the supported size")
        if ell >= INT32_LIMIT:
            raise ValueError(
                f"int32 ELL overflow: n_local_max * maxd = {n_local_max} * "
                f"{max(maxd, maxd2, 1)} = {ell} >= {INT32_LIMIT}; partition "
                f"over more workers (larger P) to shrink the per-shard tile")
    return IdPolicy(
        n_global=n_global, ell=ell,
        id_dtype=np.int64 if n_global >= INT32_LIMIT else np.int32,
        ell_dtype=np.int64 if ell >= INT32_LIMIT else np.int32)


def check_int32_limits(n_global: int, n_local_max: int, maxd: int,
                       maxd2: int = 0) -> None:
    """Historical hard int32 guard — now a thin ``id_policy`` wrapper.

    Raises exactly where the pre-policy guard raised; callers that can
    handle the int64 regime should consume ``id_policy`` directly.
    """
    id_policy(n_global, n_local_max, maxd, maxd2, allow_int64=False)


def _pad2(rows: list[np.ndarray], width: int, fill: int) -> np.ndarray:
    out = np.full((len(rows), width), fill, dtype=np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


def _unique_pairs(a: np.ndarray, b: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Sort index pairs by (a, b) and drop duplicates — no packed keys."""
    order = np.lexsort((b, a))
    a, b = a[order], b[order]
    keep = np.empty(a.shape[0], dtype=bool)
    keep[:1] = True
    keep[1:] = (a[1:] != a[:-1]) | (b[1:] != b[:-1])
    return a[keep], b[keep]


def _pair_diff(a2: np.ndarray, b2: np.ndarray, a1: np.ndarray,
               b1: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Set-difference of *deduped* pair lists: (a2, b2) minus (a1, b1).

    One lexsort over the concatenation with a membership tag: a pair of the
    second list survives unless the (unique) copy from the first list sorts
    immediately before it.  Output stays sorted by (a, b).
    """
    a = np.concatenate([a1, a2])
    b = np.concatenate([b1, b2])
    tag = np.concatenate([np.zeros(a1.shape[0], bool),
                          np.ones(a2.shape[0], bool)])
    order = np.lexsort((tag, b, a))
    a, b, tag = a[order], b[order], tag[order]
    dup = np.zeros(a.shape[0], bool)
    dup[1:] = (a[1:] == a[:-1]) & (b[1:] == b[:-1])
    keep = tag & ~dup
    return a[keep], b[keep]


def _two_hop_pairs(g: Graph, lo: int, row: np.ndarray, nbrs: np.ndarray,
                   chunk_paths: int = 1 << 22
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Unique (local row, global id) pairs at graph distance exactly 2.

    Expands every length-2 path v -> w -> u from the block's vertices v (the
    middle vertex w may be local or remote), then drops u == v and the pairs
    already adjacent — the direct neighbourhood lives in ``nbr`` and the D2
    kernels OR both bitsets, so keeping strict two-hop rows only is what
    bounds the ELL width.  The expansion is chunked (a hub of degree d
    contributes d² raw paths) with an incremental dedup, so peak host memory
    tracks the deduped two-hop set plus ``chunk_paths``, not the raw path
    count.
    """
    deg = (g.indptr[nbrs + 1] - g.indptr[nbrs]).astype(np.int64)
    cum = np.cumsum(deg)
    row2 = np.empty(0, np.int64)
    nb2 = np.empty(0, np.int64)
    start = 0
    while start < nbrs.shape[0]:
        base = cum[start - 1] if start else 0
        end = max(start + 1, int(np.searchsorted(cum, base + chunk_paths,
                                                 side="right")))
        end = min(end, nbrs.shape[0])
        w, d = nbrs[start:end], deg[start:end]
        starts = g.indptr[w].astype(np.int64)
        offs2 = np.cumsum(d) - d
        pos = np.arange(int(d.sum()), dtype=np.int64) - np.repeat(offs2, d)
        u = g.indices[np.repeat(starts, d) + pos].astype(np.int64)
        v = np.repeat(row[start:end].astype(np.int64), d)
        keep = u != v + lo
        row2, nb2 = _unique_pairs(np.concatenate([row2, v[keep]]),
                                  np.concatenate([nb2, u[keep]]))
        start = end
    row2, nb2 = _pair_diff(row2, nb2, row.astype(np.int64),
                           nbrs.astype(np.int64))
    return row2.astype(np.int32), nb2.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """Static sparse-exchange schedule (paper's neighbour-to-neighbour sends).

    The processor ring is walked by *shift*: in round ``r`` every shard p
    sends one buffer to ``(p + shifts[r]) % P`` via ``ppermute``.  Only
    shifts with traffic on at least one ordered pair exist, and every round
    is padded to its own global width — so both the round count and the
    bytes scale with the realized cross-edge structure, not with P.

    **Shape stability** (DESIGN.md §2): the *padded* round widths
    (``widths``, the compiled buffer shapes and hence part of every jit
    cache key via ``static``) are quantized to pow2 rungs by default, so
    near-sized graphs share one compiled exchange program.  The true pmax
    payload counts survive as ``exact_widths``: padding rows are inert
    (sentinel slots no receiver reads), and ``arrays()`` ships the exact
    widths as *data* (``round_widths``) so measured ``wire_bytes`` stay
    those of the exact plan — bitwise what an unquantized run reports.

    ``send_slot[p, r]`` lists the local boundary slots whose colors the
    round-r destination actually reads (its ghosts owned by p, in ascending
    global id), sentinel-padded to ``widths[r]`` ≤ ``max_send``.  On the
    receive side, ghost g of shard p was sent by its owner in round
    ``shift_to_round[ghost_shift[p, g]]`` at buffer position
    ``ghost_pos[p, g]``.
    """

    shifts: tuple          # static nonzero ring shifts with any traffic
    widths: tuple          # per-shift *padded* buffer width (pow2 rung)
    exact_widths: tuple    # per-shift true pmax payload width (<= widths)
    max_send: int          # max(widths), the send_slot pad width
    n_send: np.ndarray     # (P, P) per-(src, dst) payload counts
    send_slot: np.ndarray  # (P, n_rounds, max_send) local slots, pad=sentinel
    ghost_shift: np.ndarray  # (P, max_ghost) ring shift of each ghost, pad=-1
    ghost_pos: np.ndarray    # (P, max_ghost) position in owner's send row
    shift_to_round: np.ndarray  # (P, P) shift value -> round index, -1 unused

    @property
    def static(self) -> tuple:
        """Hashable (shifts, padded widths) — part of the jit cache key."""
        return (self.shifts, self.widths)

    def arrays(self) -> dict[str, np.ndarray]:
        P = self.send_slot.shape[0]
        rw = np.zeros((max(len(self.shifts), 1),), np.int32)
        rw[:len(self.exact_widths)] = self.exact_widths
        return dict(send_slot=self.send_slot, ghost_shift=self.ghost_shift,
                    ghost_pos=self.ghost_pos,
                    shift_to_round=self.shift_to_round,
                    round_widths=np.broadcast_to(rw, (P, rw.shape[0])).copy())

    def bytes_per_exchange(self, itemsize: int = 4, round_mask=None, *,
                           padded: bool = False) -> int:
        """Per-shard wire bytes of one sparse exchange.

        ``round_mask`` (bool per round) models a partial exchange — the cost
        of shipping only the masked ``ppermute`` rounds (recolor's per-link
        piggybacking); ``None`` means a full exchange.  Default: the *exact*
        plan bytes (the paper's model; what ``stats["wire_bytes"]``
        measures).  ``padded=True`` counts the pow2-rung buffer widths the
        compiled program physically ships — the quantity the trace-time
        sparse-vs-allgather decision compares (``pipeline.resolve_scheme``).
        """
        ws = self.widths if padded else self.exact_widths
        if round_mask is None:
            return int(sum(ws)) * itemsize
        return int(sum(w for w, m in zip(ws, round_mask) if m)) * itemsize


@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Per-processor padded arrays, stacked on a leading P axis (host, numpy).

    Per-shard slot index arrays are int32; the global-id arrays
    (``gvid``/``prio``) follow ``id_policy`` — int32 below the 2**31
    vertex bound, int64 past it.  `n_slots = n_local_max + max_ghost + 1`.
    """

    P: int
    n_global: int
    n_local_max: int
    max_ghost: int
    max_boundary: int
    m_local_max: int
    maxd: int
    offs: np.ndarray           # (P+1,) block boundaries in global ids
    n_local: np.ndarray        # (P,)
    n_ghost: np.ndarray        # (P,)
    n_boundary: np.ndarray     # (P,)
    indptr: np.ndarray         # (P, n_local_max+1)
    indices: np.ndarray        # (P, m_local_max) slot ids, pad=sentinel
    nbr: np.ndarray            # (P, n_local_max, maxd) ELL slot ids, pad=sentinel
    edge_src: np.ndarray       # (P, m_local_max) local row per edge, pad=n_local_max
    boundary: np.ndarray       # (P, max_boundary) local slots, pad=sentinel
    ghost_owner: np.ndarray    # (P, max_ghost)
    ghost_slot: np.ndarray     # (P, max_ghost)
    gvid: np.ndarray           # (P, n_slots) global vertex id per slot, pad=-1
    prio: np.ndarray           # (P, n_slots) random tie-break priority, pad=-1
    is_internal: np.ndarray    # (P, n_local_max) bool
    degree: np.ndarray         # (P, n_local_max) int32 local-graph-visible degree
    halo: int = 1              # ghost depth: 1 (D1) or 2 (two-hop halo, D2)
    maxd2: int = 0             # max strict-two-hop row width (halo=2 only)
    nbr2: np.ndarray | None = None  # (P, n_local_max, maxd2) two-hop ELL
                                    # slot ids, pad=sentinel (halo=2 only)
    quantize_plan: bool = True  # pow2-rung round widths in ``comm_plan``
                                # (compile-stable plans; byte accounting
                                # stays exact — DESIGN.md §2)

    @property
    def n_slots(self) -> int:
        return self.n_local_max + self.max_ghost + 1

    @property
    def sentinel(self) -> int:
        return self.n_slots - 1

    @property
    def n_interior(self) -> np.ndarray:
        """(P,) count of interior (no ghost neighbour) local vertices."""
        return self.is_internal.sum(axis=1).astype(np.int32)

    @functools.cached_property
    def comm_plan(self) -> CommPlan:
        """Sparse-exchange schedule; built once, cached on the instance."""
        return build_comm_plan(self)

    def arrays(self, *, sparse: bool = True) -> dict[str, np.ndarray]:
        """Device-ready dict (everything that the JAX kernels consume).

        ``sparse=False`` (all-gather-only runs) skips building and shipping
        the sparse-exchange plan arrays — they would be traced-out anyway,
        but the host-side plan build and host-to-device transfers are not.
        """
        out = dict(
            n_local=self.n_local.astype(np.int32),
            indptr=self.indptr,
            indices=self.indices,
            nbr=self.nbr,
            edge_src=self.edge_src,
            boundary=self.boundary,
            ghost_owner=self.ghost_owner,
            ghost_slot=self.ghost_slot,
            prio=self.prio,
            is_internal=self.is_internal,
            degree=self.degree,
        )
        if self.nbr2 is not None:
            out["nbr2"] = self.nbr2
        if sparse:
            out.update(self.comm_plan.arrays())
        return out

    def gather_global_colors(self, local_colors: np.ndarray) -> np.ndarray:
        """(P, n_slots) or (P, n_local_max) device views -> (n_global,) colors."""
        out = np.zeros(self.n_global, dtype=local_colors.dtype)
        for p in range(self.P):
            nl = int(self.n_local[p])
            out[self.offs[p] : self.offs[p] + nl] = local_colors[p, :nl]
        return out


def partition_graph(g: Graph, P: int, *, seed: int = 0,
                    permute: bool = False, halo: int = 1) -> PartitionedGraph:
    """Block-partition `g` onto P processors and build the device layout.

    ``permute=True`` applies a random vertex permutation first (a stand-in for
    a different partitioner; block partitioning on RMAT matches the paper).

    ``halo=2`` builds the two-hop halo for distance-2 coloring: the ghost
    tables extend to every remote vertex within two hops, ``nbr2`` carries the
    strict two-hop neighbourhood in ELL form, and ``boundary``/``is_internal``
    widen to "this color is read by some other shard".  The comm plan and both
    exchange schemes are halo-agnostic — depth-2 ghosts are ordinary
    ghost-table entries (sorted by global id, hence owner-contiguous) and ride
    the same ring-shift ``ppermute`` schedule.
    """
    assert halo in (1, 2), f"halo must be 1 or 2, got {halo}"
    rng = np.random.default_rng(seed)
    # global-id width from n alone; the ELL verdict is re-derived below once
    # maxd is known (id_policy is the single id-width decision point)
    id_dt = id_policy(g.n, 1, 1).id_dtype
    if permute:
        perm = rng.permutation(g.n).astype(id_dt)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(g.n, dtype=id_dt)
        deg = g.degrees
        new_indptr = np.zeros(g.n + 1, dtype=np.int64)
        new_indptr[1:] = np.cumsum(deg[perm])
        new_indices = np.empty_like(g.indices)
        for new_v in range(g.n):  # pragma: no cover - only used in slow paths
            old_v = perm[new_v]
            s, e = g.indptr[old_v], g.indptr[old_v + 1]
            new_indices[new_indptr[new_v] : new_indptr[new_v + 1]] = inv[g.indices[s:e]]
        g = Graph(g.n, new_indptr, new_indices)

    offs = np.linspace(0, g.n, P + 1).astype(np.int64)
    owner_of = np.searchsorted(offs, np.arange(g.n), side="right") - 1
    prio_global = rng.permutation(g.n).astype(id_dt)  # random total order (§2.2)

    n_local = (offs[1:] - offs[:-1]).astype(np.int32)
    n_local_max = int(n_local.max())

    # pass 1: per-shard edge slices, halo sets (the remote vertices whose
    # colors this shard reads) and, at halo=2, the strict two-hop pair lists
    ghosts_of: list[np.ndarray] = []
    edge_of: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    hop2: list[tuple[np.ndarray, np.ndarray, np.ndarray] | None] = []
    for p in range(P):
        lo, hi = int(offs[p]), int(offs[p + 1])
        nl = hi - lo
        nbrs = g.indices[g.indptr[lo] : g.indptr[hi]]
        row = np.repeat(np.arange(nl, dtype=np.int32),
                        np.diff(g.indptr[lo : hi + 1]).astype(np.int32))
        remote = (nbrs < lo) | (nbrs >= hi)
        edge_of.append((nbrs, row, remote))
        if halo == 1:
            # ghosts: unique remote neighbours (searchsorted-friendly order)
            ghosts_of.append(np.unique(nbrs[remote]))
            hop2.append(None)
        else:
            row2, nb2 = _two_hop_pairs(g, lo, row, nbrs)
            rem2 = (nb2 < lo) | (nb2 >= hi)
            ghosts_of.append(np.unique(np.concatenate(
                [nbrs[remote], nb2[rem2]])))
            hop2.append((row2, nb2, rem2))

    # boundary = local vertices some other shard reads, i.e. members of
    # another shard's halo set.  At halo=1 this is exactly "has a remote
    # neighbour" (the adjacency is symmetric); at halo=2 it widens to the
    # two-hop fringe.
    read_remote = np.zeros(g.n, dtype=bool)
    for gh in ghosts_of:
        read_remote[gh] = True

    rows_indptr, rows_indices, rows_src = [], [], []
    rows_boundary, rows_gowner = [], []
    rows_internal, rows_degree = [], []
    n_ghost = np.zeros(P, dtype=np.int32)
    n_boundary = np.zeros(P, dtype=np.int32)

    for p in range(P):
        lo, hi = int(offs[p]), int(offs[p + 1])
        nl = hi - lo
        nbrs, row, remote = edge_of[p]
        gh = ghosts_of[p]
        slots = np.where(remote, 0, nbrs - lo).astype(np.int32)
        if remote.any():
            slots[remote] = (n_local_max
                             + np.searchsorted(gh, nbrs[remote])).astype(
                                 np.int32)
        is_bnd = read_remote[lo:hi].copy()
        bnd = np.nonzero(is_bnd)[0].astype(np.int32)
        n_boundary[p] = len(bnd)
        n_ghost[p] = len(gh)

        rows_indptr.append(np.diff(g.indptr[lo : hi + 1]).astype(np.int32))
        rows_indices.append(slots)
        rows_src.append(row)
        rows_boundary.append(bnd)
        gowner = owner_of[gh].astype(np.int32) if len(gh) else np.zeros(0, np.int32)
        rows_gowner.append(gowner)
        rows_internal.append(~is_bnd)
        rows_degree.append(np.diff(g.indptr[lo : hi + 1]).astype(np.int32))

    # Resolve ghost -> (owner, slot-in-owner-boundary-payload) via one global
    # boundary-slot table (vectorized; P=512 × millions of edges stays fast).
    bslot_global = np.full(g.n, -1, dtype=np.int32)
    for p in range(P):
        lo = int(offs[p])
        bslot_global[rows_boundary[p] + lo] = np.arange(
            len(rows_boundary[p]), dtype=np.int32)
    gslot_rows = [bslot_global[gh] for gh in ghosts_of]

    max_ghost = max(1, int(n_ghost.max()))
    max_boundary = max(1, int(n_boundary.max()))
    m_local_max = max(1, max(len(r) for r in rows_indices))
    n_slots = n_local_max + max_ghost + 1
    sentinel = n_slots - 1

    indptr = np.zeros((P, n_local_max + 1), dtype=np.int32)
    gvid = np.full((P, n_slots), -1, dtype=id_dt)
    prio = np.full((P, n_slots), -1, dtype=id_dt)
    is_internal = np.zeros((P, n_local_max), dtype=bool)
    degree = np.zeros((P, n_local_max), dtype=np.int32)
    for p in range(P):
        nl = int(n_local[p])
        indptr[p, 1 : nl + 1] = np.cumsum(rows_indptr[p])
        indptr[p, nl + 1 :] = indptr[p, nl]
        gh, lo = ghosts_of[p], int(offs[p])
        gvid[p, :nl] = np.arange(lo, lo + nl, dtype=id_dt)
        gvid[p, n_local_max : n_local_max + len(gh)] = gh
        prio[p, :nl] = prio_global[lo : lo + nl]
        prio[p, n_local_max : n_local_max + len(gh)] = prio_global[gh]
        is_internal[p, :nl] = rows_internal[p]
        degree[p, :nl] = rows_degree[p]

    # remap ghost slot-ids in `indices` (they were built against per-p ghost
    # numbering which already starts at n_local_max) and pad
    indices = _pad2(rows_indices, m_local_max, sentinel)
    edge_src = _pad2(rows_src, m_local_max, n_local_max)

    # ELL form of the same adjacency: nbr[p, v, k] = k-th neighbour slot of v,
    # padded with the sentinel (color 0, ignored by the selection kernels).
    maxd = max(1, max(int(r.max(initial=0)) for r in rows_indptr))
    id_policy(g.n, n_local_max, maxd)  # before the ELL allocation: raises
                                       # only past the int64 ceiling
    nbr = np.full((P, n_local_max, maxd), sentinel, dtype=np.int32)
    for p in range(P):
        deg_p = rows_indptr[p].astype(np.int64)
        starts = np.concatenate([[0], np.cumsum(deg_p)])[:-1]
        row = rows_src[p].astype(np.int64)
        col = np.arange(len(row), dtype=np.int64) - starts[row]
        nbr[p, row, col] = rows_indices[p]
    boundary = _pad2(rows_boundary, max_boundary, sentinel)
    ghost_owner = _pad2(rows_gowner, max_ghost, 0)
    ghost_slot = _pad2(gslot_rows, max_ghost, 0)

    # strict two-hop ELL (halo=2): nbr2[p, v, k] = k-th distance-2 slot of v.
    # Rows come pre-sorted by (v, global id) from _two_hop_pairs, so each
    # vertex's entries are one contiguous run.
    maxd2, nbr2 = 0, None
    if halo == 2:
        slot2_rows = []
        for p in range(P):
            lo = int(offs[p])
            row2, nb2, rem2 = hop2[p]
            slot2 = np.where(rem2, 0, nb2 - lo).astype(np.int32)
            if rem2.any():
                slot2[rem2] = (n_local_max + np.searchsorted(
                    ghosts_of[p], nb2[rem2])).astype(np.int32)
            slot2_rows.append((row2, slot2))
            cnt = np.bincount(row2, minlength=1)
            maxd2 = max(maxd2, int(cnt.max(initial=0)))
        maxd2 = max(1, maxd2)
        id_policy(g.n, n_local_max, maxd, maxd2)
        nbr2 = np.full((P, n_local_max, maxd2), sentinel, dtype=np.int32)
        for p in range(P):
            row2, slot2 = slot2_rows[p]
            cnt = np.bincount(row2, minlength=n_local_max).astype(np.int64)
            starts2 = np.concatenate([[0], np.cumsum(cnt)])[:-1]
            col = np.arange(len(row2), dtype=np.int64) - starts2[row2]
            nbr2[p, row2, col] = slot2

    return PartitionedGraph(
        P=P, n_global=g.n, n_local_max=n_local_max, max_ghost=max_ghost,
        max_boundary=max_boundary, m_local_max=m_local_max, maxd=maxd,
        offs=offs, n_local=n_local, n_ghost=n_ghost, n_boundary=n_boundary,
        indptr=indptr, indices=indices, nbr=nbr, edge_src=edge_src,
        boundary=boundary, ghost_owner=ghost_owner, ghost_slot=ghost_slot,
        gvid=gvid, prio=prio, is_internal=is_internal, degree=degree,
        halo=halo, maxd2=maxd2, nbr2=nbr2,
    )


def pad_partition(pg: PartitionedGraph, *, n_local_max: int | None = None,
                  max_ghost: int | None = None, max_boundary: int | None = None,
                  m_local_max: int | None = None, maxd: int | None = None,
                  maxd2: int | None = None) -> PartitionedGraph:
    """Re-pad a partition to larger target maxima (same graph, same blocks).

    The batched multi-graph pipeline (DESIGN.md §8) stacks several
    partitioned graphs on a leading axis, which requires every padded
    dimension to agree across the batch.  This widens the device layout of
    ``pg`` to the given targets and remaps every slot id to the new
    numbering: local slots are unchanged, ghost slots shift by
    ``n_local_max - pg.n_local_max``, and the sentinel moves to the new
    ``n_slots - 1``.  New padding entries are inert by construction (ELL
    pads point at the sentinel, order/``gvid``/``prio`` pads are -1, padded
    local rows have no neighbours and are never visited), so any driver run
    on the padded partition colors the same graph.

    NOTE: padding is *not* bitwise-neutral for randomized selection —
    per-slot random draws (Random-X Fit) depend on ``n_slots``, so a padded
    run is reproducible against runs at the same padded shape, not against
    the unpadded one.  First-Fit/Staggered paths are shape-independent.
    """
    new_nlm = pg.n_local_max if n_local_max is None else int(n_local_max)
    new_mg = pg.max_ghost if max_ghost is None else int(max_ghost)
    new_mb = pg.max_boundary if max_boundary is None else int(max_boundary)
    new_ml = pg.m_local_max if m_local_max is None else int(m_local_max)
    new_maxd = pg.maxd if maxd is None else int(maxd)
    new_maxd2 = pg.maxd2 if maxd2 is None else int(maxd2)
    assert new_nlm >= pg.n_local_max and new_mg >= pg.max_ghost
    assert new_mb >= pg.max_boundary and new_ml >= pg.m_local_max
    assert new_maxd >= pg.maxd and new_maxd2 >= pg.maxd2
    if (new_nlm, new_mg, new_mb, new_ml, new_maxd, new_maxd2) == (
            pg.n_local_max, pg.max_ghost, pg.max_boundary, pg.m_local_max,
            pg.maxd, pg.maxd2):
        return pg

    P = pg.P
    old_nlm, old_sent = pg.n_local_max, pg.sentinel
    new_sent = new_nlm + new_mg
    d_ghost = new_nlm - old_nlm

    def remap(a: np.ndarray) -> np.ndarray:
        """Old-layout slot ids -> new layout (locals keep, ghosts shift)."""
        out = np.where(a >= old_nlm, a + d_ghost, a)
        return np.where(a == old_sent, new_sent, out).astype(np.int32)

    def pad_axis(a: np.ndarray, axis: int, width: int, fill) -> np.ndarray:
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, width - a.shape[axis])
        return np.pad(a, pad, constant_values=fill)

    indptr = pad_axis(pg.indptr, 1, new_nlm + 1, 0)
    indptr[:, old_nlm + 1:] = indptr[:, old_nlm:old_nlm + 1]
    indices = pad_axis(remap(pg.indices), 1, new_ml, new_sent)
    edge_src = np.where(pg.edge_src == old_nlm, new_nlm, pg.edge_src)
    edge_src = pad_axis(edge_src.astype(np.int32), 1, new_ml, new_nlm)
    nbr = pad_axis(pad_axis(remap(pg.nbr), 2, new_maxd, new_sent),
                   1, new_nlm, new_sent)
    boundary = pad_axis(remap(pg.boundary), 1, new_mb, new_sent)
    ghost_owner = pad_axis(pg.ghost_owner, 1, new_mg, 0)
    ghost_slot = pad_axis(pg.ghost_slot, 1, new_mg, 0)
    gvid = np.full((P, new_sent + 1), -1, dtype=pg.gvid.dtype)
    prio = np.full((P, new_sent + 1), -1, dtype=pg.prio.dtype)
    gvid[:, :old_nlm] = pg.gvid[:, :old_nlm]
    gvid[:, new_nlm:new_nlm + pg.max_ghost] = pg.gvid[:, old_nlm:old_sent]
    prio[:, :old_nlm] = pg.prio[:, :old_nlm]
    prio[:, new_nlm:new_nlm + pg.max_ghost] = pg.prio[:, old_nlm:old_sent]
    is_internal = pad_axis(pg.is_internal, 1, new_nlm, False)
    degree = pad_axis(pg.degree, 1, new_nlm, 0)
    nbr2 = None
    if pg.nbr2 is not None:
        nbr2 = pad_axis(pad_axis(remap(pg.nbr2), 2, max(new_maxd2, 1),
                                 new_sent), 1, new_nlm, new_sent)

    return dataclasses.replace(
        pg, n_local_max=new_nlm, max_ghost=new_mg, max_boundary=new_mb,
        m_local_max=new_ml, maxd=new_maxd, maxd2=new_maxd2,
        indptr=indptr, indices=indices, nbr=nbr, edge_src=edge_src,
        boundary=boundary, ghost_owner=ghost_owner, ghost_slot=ghost_slot,
        gvid=gvid, prio=prio, is_internal=is_internal, degree=degree,
        nbr2=nbr2)


def plan_fits(plan: CommPlan, static: tuple) -> bool:
    """True iff ``plan`` embeds into the target ``(shifts, widths)`` schedule.

    Fits = every traffic-bearing ring shift of ``plan`` exists in the
    target and the target's (pow2-rung) buffer width covers the plan's.
    A fitting partition can execute the target's compiled exchange rounds
    bitwise-inertly (sentinel rows on foreign rounds, exact
    ``round_widths`` as data) — the admission gate the continuous serving
    engine probes before swapping a new graph into a freed lane
    (DESIGN.md §11).
    """
    shifts, widths = static
    w = dict(zip(shifts, widths))
    return all(k in w and pw <= w[k]
               for k, pw in zip(plan.shifts, plan.widths))


def remap_plan_arrays(pg, static: tuple) -> dict[str, np.ndarray]:
    """``pg``'s sparse-plan arrays re-laid onto a target static schedule.

    Rounds ``pg`` has no traffic on get an all-sentinel send row (its
    ghosts never match the shift, so the round cannot move its view) and a
    zero in its ``round_widths`` vector — the traced byte-accounting
    override (``comm.exchange_sparse``) that keeps the measured
    ``wire_bytes`` identical to a solo run under ``pg``'s own *exact*
    plan.  This is the mechanism behind both the batched bucket's shared
    schedule (``_union_comm_arrays``) and the serving engine's mid-flight
    lane admission: the target schedule is trace-static, the member's
    rounds are data.  Raises ``ValueError`` when ``plan_fits`` is False.
    """
    shifts, widths = static
    pl = pg.comm_plan
    if not plan_fits(pl, static):
        raise ValueError(f"comm plan {pl.static} does not fit the target "
                         f"schedule {static}")
    P = pg.P
    max_send = max(widths, default=0)
    n_rounds = max(len(shifts), 1)
    s2r = np.full((P,), -1, dtype=np.int32)
    for r, k in enumerate(shifts):
        s2r[k] = r
    w = dict(zip(pl.shifts, pl.widths))
    ex = dict(zip(pl.shifts, pl.exact_widths))
    send = np.full((P, n_rounds, max(max_send, 1)), pg.sentinel, np.int32)
    rw = np.zeros((n_rounds,), np.int32)
    for r, k in enumerate(shifts):
        if k in w:
            rm = pl.shifts.index(k)
            send[:, r, :pl.send_slot.shape[2]] = pl.send_slot[:, rm]
            rw[r] = ex[k]
    return dict(
        send_slot=send, ghost_shift=pl.ghost_shift, ghost_pos=pl.ghost_pos,
        shift_to_round=np.broadcast_to(s2r, (P, P)).copy(),
        round_widths=np.broadcast_to(rw, (P, n_rounds)).copy())


def _union_comm_arrays(members) -> tuple[tuple, list[dict[str, np.ndarray]]]:
    """One shared sparse round schedule for a bucket of padded partitions.

    The sparse exchange unrolls a *static* ``(shifts, widths)`` schedule
    (part of the jit cache key), so every graph in a batch must execute the
    same rounds.  The shared schedule is the union of the members' ring
    shifts, each padded to the bucket-max (pow2-rung) buffer width; every
    member's arrays are then re-laid onto it with ``remap_plan_arrays``
    (sentinel rows on foreign rounds keep each lane bitwise-inert).

    Returns ``((shifts, widths), per-member array dicts)`` where each dict
    carries ``send_slot``/``ghost_shift``/``ghost_pos``/``shift_to_round``
    in the shared schedule plus ``round_widths`` ``(P, n_rounds)`` int32.
    """
    plans = [m.comm_plan for m in members]
    width_of = [dict(zip(pl.shifts, pl.widths)) for pl in plans]
    shifts = tuple(sorted({k for pl in plans for k in pl.shifts}))
    widths = tuple(max(w.get(k, 0) for w in width_of) for k in shifts)
    static = (shifts, widths)
    return static, [remap_plan_arrays(m, static) for m in members]


@dataclasses.dataclass(frozen=True)
class GraphBucket:
    """Same-shape padded partitions, stackable on a leading graph axis.

    Built by ``bucket_graphs``.  ``members[j]`` is the padded partition of
    input graph ``indices[j]``; every padded dimension (and hence every
    device-array shape) agrees across members, so ``stacked_arrays`` returns
    ``(B, P, ...)`` arrays the batched pipeline can vmap over.  The sparse
    comm schedule is the members' union (``plan_static``), with per-member
    ``round_widths`` keeping measured wire bytes exact per graph.
    """

    indices: tuple   # positions of the members in the bucket_graphs() input
    members: tuple   # PartitionedGraph instances, padded to shared dims

    @property
    def B(self) -> int:
        return len(self.members)

    @property
    def P(self) -> int:
        return self.members[0].P

    @functools.cached_property
    def _union_plan(self) -> tuple[tuple, list[dict[str, np.ndarray]]]:
        return _union_comm_arrays(self.members)

    @property
    def plan_static(self) -> tuple:
        """Hashable shared ``(shifts, widths)`` — the batch's jit cache key."""
        return self._union_plan[0]

    def member_arrays(self, j: int, *, sparse: bool = True) -> dict:
        """Device dict of member ``j`` under the *shared* comm schedule."""
        out = self.members[j].arrays(sparse=False)
        if sparse:
            out = dict(out, **self._union_plan[1][j])
        return out

    def stacked_arrays(self, *, sparse: bool = True) -> dict[str, np.ndarray]:
        """All members stacked on a leading graph axis: ``(B, P, ...)``.

        Cached per ``sparse`` flag: a memoized serving bucket re-dispatches
        the same stacked inputs on every warm solo hit, so the stack copy
        must not be a per-request cost.
        """
        cache = self.__dict__.setdefault("_stacked", {})
        if sparse not in cache:
            per = [self.member_arrays(j, sparse=sparse)
                   for j in range(self.B)]
            cache[sparse] = {k: np.stack([d[k] for d in per])
                             for k in per[0]}
        return cache[sparse]


def _ceil_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


def bucket_graphs(pgs, *, round_pow2: bool = True) -> list:
    """Group partitioned graphs into shape buckets for batched execution.

    Bucket key: ``(P, halo, n_local_max, maxd, maxd2)`` with the size-like
    dims rounded up to the next power of two (``round_pow2=True``, the
    default) so near-sized graphs share one bucket and one compiled program
    at <= 2x padding waste per keyed dim; ``round_pow2=False`` groups only
    exactly-matching dims.  Within a bucket every member is re-padded
    (``pad_partition``) to the bucket ceilings; the remaining pad widths
    (``max_ghost``/``max_boundary``/``m_local_max``) take the member max,
    also pow2-rounded by default — with every padded dim a power of two,
    a long-running service's bucket *shapes* are stable across request
    waves, so the compiled batch programs keep hitting the jit cache
    (``color_many(pad_batch=True)`` stabilizes the batch axis the same
    way).  Members must already share ``P`` and ``halo`` to share a bucket.

    Returns ``GraphBucket`` objects covering the input exactly;
    ``bucket.indices`` maps members back to input positions.
    """
    rnd = _ceil_pow2 if round_pow2 else int
    groups: dict[tuple, list[int]] = {}
    for i, pg in enumerate(pgs):
        key = (pg.P, pg.halo, rnd(pg.n_local_max), rnd(pg.maxd),
               rnd(pg.maxd2) if pg.halo == 2 else 0)
        groups.setdefault(key, []).append(i)
    buckets = []
    for key in sorted(groups):
        idx = groups[key]
        mem = [pgs[i] for i in idx]
        members = tuple(pad_partition(
            m, n_local_max=key[2], maxd=key[3],
            maxd2=key[4] if key[1] == 2 else 0,
            max_ghost=rnd(max(x.max_ghost for x in mem)),
            max_boundary=rnd(max(x.max_boundary for x in mem)),
            m_local_max=rnd(max(x.m_local_max for x in mem))) for m in mem)
        buckets.append(GraphBucket(indices=tuple(idx), members=members))
    return buckets


def build_comm_plan(pg: PartitionedGraph, *,
                    quantize: bool | None = None) -> CommPlan:
    """Derive the sparse neighbour-to-neighbour schedule from the ghosts.

    Shard q's ghosts are sorted by global vertex id, and block partitioning
    makes ``owner`` monotone in the id — so the ghosts owned by one shard p
    form one contiguous, ascending run.  That run *is* p's send list to q
    (the boundary colors q actually reads), and the position of each ghost
    inside its run is the receive-side gather index.  Both sides are derived
    from the same pass, so they agree by construction.

    ``quantize`` (default ``pg.quantize_plan``, i.e. on) rounds every
    round's *buffer* width up to the next power of two so the plan's static
    part — the jit cache key — takes few distinct values across graphs of
    similar structure (DESIGN.md §2).  The padding entries are sentinel
    slots no receiver ever reads, and byte accounting keeps using the exact
    widths, so a quantized run is bitwise an exact-plan run.
    """
    P = pg.P
    n_send = np.zeros((P, P), dtype=np.int32)
    send_lists: dict[tuple[int, int], np.ndarray] = {}
    ghost_pos = np.zeros((P, pg.max_ghost), dtype=np.int32)
    ghost_shift = np.full((P, pg.max_ghost), -1, dtype=np.int32)

    for q in range(P):
        ng = int(pg.n_ghost[q])
        if ng == 0:
            continue
        owners = pg.ghost_owner[q, :ng]
        vids = pg.gvid[q, pg.n_local_max : pg.n_local_max + ng]
        # contiguous owner runs (owners monotone: vids sorted, blocks ordered)
        starts = np.flatnonzero(np.r_[True, owners[1:] != owners[:-1]])
        ends = np.r_[starts[1:], ng]
        for s, e in zip(starts, ends):
            p = int(owners[s])
            send_lists[(p, q)] = (vids[s:e] - pg.offs[p]).astype(np.int32)
            n_send[p, q] = e - s
            ghost_pos[q, s:e] = np.arange(e - s, dtype=np.int32)
            ghost_shift[q, s:e] = (q - p) % P

    # retain only ring shifts with any traffic; each round pads to its own
    # global (pmax) width, pow2-rung-rounded when the plan is quantized
    srcs, dsts = np.nonzero(n_send)
    all_shifts = (dsts - srcs) % P
    shifts = tuple(int(k) for k in np.unique(all_shifts))
    exact_widths = tuple(
        int(n_send[np.arange(P), (np.arange(P) + k) % P].max())
        for k in shifts)
    if quantize is None:
        quantize = pg.quantize_plan
    widths = (tuple(_ceil_pow2(w) for w in exact_widths) if quantize
              else exact_widths)
    max_send = max(widths, default=0)

    send_slot = np.full((P, max(len(shifts), 1), max(max_send, 1)),
                        pg.sentinel, dtype=np.int32)
    for r, k in enumerate(shifts):
        for p in range(P):
            q = (p + k) % P
            sl = send_lists.get((p, q))
            if sl is not None:
                send_slot[p, r, : len(sl)] = sl

    shift_to_round = np.full((P,), -1, dtype=np.int32)
    for r, k in enumerate(shifts):
        shift_to_round[k] = r

    return CommPlan(
        shifts=shifts, widths=widths, exact_widths=exact_widths,
        max_send=max_send, n_send=n_send,
        send_slot=send_slot, ghost_shift=ghost_shift, ghost_pos=ghost_pos,
        shift_to_round=np.broadcast_to(shift_to_round, (P, P)).copy(),
    )
