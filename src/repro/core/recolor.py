"""Distributed iterative recoloring (paper §3) — the core contribution.

Synchronous recoloring (RC): given a valid K-coloring, recolor in K steps.
Step ``t`` first-fit-colors the whole color class ``perm(t)`` — an independent
set, so the step is *fully data-parallel* (vectorized over the class on TPU,
no intra-step ordering) and the procedure is conflict-free by construction;
distributed RC equals sequential RC for the same seed coloring (§3, tested).

The step loop is *work-efficient* (DESIGN.md §4): vertices are sorted by
class step once, and each step processes only its own class as fixed-size
chunks of the sorted order — an ELL-row gather of neighbour colors followed
by bitset first-fit through ``kernels.ops.select_colors`` (Pallas on TPU,
the same math vectorized under XLA elsewhere).  Total selection work per
iteration is O(V · maxd / 32) words instead of the K · O(V · max_colors)
bytes a per-step dense occupancy would scatter.  Chunk counts per class are
pmax-reduced, so every shard runs the same loop trip count and the collective
schedule stays uniform (a shard_map requirement).

Color-class permutations (§3): RV (reverse), NI (non-increasing class size),
ND (non-decreasing — the paper's best), RAND (Knuth shuffle), and the hybrid
schedules ND-RAND%x / ND-RAND%2^i handled by `recolor_iterations`.

Piggybacking (§3.1) becomes *exchange-step coalescing* on TPU: a ghost color
assigned at step s is only needed by a local reader at step t>s, so the
boundary exchange after step s can be deferred to step t-1; everything
pending rides that one collective ("piggybacks"). The pre-communication of
the paper — "who receives at which step" — is the OR-reduce (pmax) of each
shard's needed-step bitmap. `needed[K]` is the end-of-iteration exchange that
carries all remaining deferred colors.  Under the sparse scheme
(`RecolorConfig.scheme`, DESIGN.md §2) the bitmap is additionally refined
*per link*: each dependency marks only the ppermute round of its writer's
ring shift, so an exchange event ships just the rounds some destination
still needs.

Asynchronous recoloring (aRC, §3): each shard *locally* orders vertices by
color class and reruns the speculative framework (conflicts possible).

Multi-iteration runs live in ``pipeline.py`` (DESIGN.md §7): the fused
``color_then_recolor`` keeps seed coloring + K iterations device-resident in
one ``lax.while_loop``; ``recolor_iterations`` below is a thin wrapper over
its recolor-only loop, with the host loop kept behind ``fused=False`` as the
bitwise reference.

Distance-2 mode (``RecolorConfig(distance=2)``, DESIGN.md §5): a class of a
valid D2 coloring is a distance-2 independent set, so the step stays
conflict-free; selection ORs the two-hop bitset and the piggyback schedule
gains the two-hop ELL rows as a second dependency source
(``_cross_deps_ell``) — a D2 reader consumes its two-hop ghosts' colors too.
Partial seed colorings need no flag here: uncolored vertices are class 0,
which every permutation ranks 0 and the step loop skips unconditionally.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .comm import (AUTO, AXIS, DEFAULT_SCHEME, SCHEME_CHOICES, SCHEMES,
                   SPARSE, AxisComm, CommConfig, exchange_boundary,
                   make_exchange, run_sharded, run_sim, shard_axis_of,
                   shard_uniform, stats_to_host)
from .graph import PartitionedGraph
from .speculative import (ColorConfig, _compact_order, _plan_static,
                          color_spmd, resolve_cfg, validate_color_bounds)

RV = "rv"
NI = "ni"
ND = "nd"
RAND = "rand"
ALL_PERMS = (RV, NI, ND, RAND)
# Integer ids for the fused pipeline's traced permutation schedule
# (``pipeline.py`` resolves the per-iteration kind with ``lax.switch``).
PERM_IDS = {kind: i for i, kind in enumerate(ALL_PERMS)}

# Driver-level call counter: manual back-to-back ``recolor_sim`` calls that
# fall back to the config seed must not replay the identical RAND permutation
# (ISSUE 4).  Callers that need reproducible keys pass ``key=`` explicitly.
_DEFAULT_KEY_CALLS = itertools.count()


def _default_key(seed: int):
    return jax.random.fold_in(jax.random.key(seed), next(_DEFAULT_KEY_CALLS))


@dataclasses.dataclass(frozen=True)
class RecolorConfig:
    """Static configuration of one recoloring iteration.

    Units: ``max_colors`` bounds the *seed* coloring's ids (32-aligned);
    ``chunk`` is vertices selected per ELL tile (clamped to the shard's
    row count at trace time).  Drivers: ``recolor_sim`` /
    ``recolor_sharded`` run one iteration (sim vs ``workers`` mesh,
    bitwise identical); ``arc_sim`` is the asynchronous variant;
    ``recolor_iterations`` / ``pipeline.PipelineConfig`` run schedules of
    iterations device-resident.
    """

    max_colors: int = 1024         # bound on colors of the SEED coloring
    piggyback: bool = True         # paper §3.1 (False = exchange every step)
    scheme: str = DEFAULT_SCHEME   # boundary exchange: "sparse" | "allgather"
                                   # | "auto" (pick by modeled bytes at trace
                                   # time; default follows $REPRO_SCHEME)
    wire16: bool = False           # int16 boundary payloads (half ICI bytes)
    chunk: int = 256               # vertices selected per chunk (ELL tile rows)
    backend: str = "auto"          # kernels.ops backend: auto | xla | pallas
    distance: int = 1              # 1 = proper; 2 = distance-2 recoloring
                                   # (needs a halo=2 PartitionedGraph and a
                                   # valid D2 seed coloring — classes must be
                                   # distance-2 independent sets)
    seed: int = 0

    def __post_init__(self):
        validate_color_bounds(self.max_colors, self.wire16, self.backend)
        assert self.scheme in SCHEME_CHOICES, f"bad scheme {self.scheme!r}"
        assert self.chunk > 0
        assert self.distance in (1, 2), f"bad distance {self.distance}"

    @property
    def n_words(self) -> int:
        return self.max_colors // 32

    @property
    def comm_config(self) -> CommConfig:
        return CommConfig(scheme=self.scheme, wire16=self.wire16)


def class_sizes(view, n_local, n_local_max, max_colors, comm: AxisComm):
    """Global color-class sizes (max_colors,) — the NI/ND pre-communication.

    Returns ``(sizes, n_out_of_range)``.  Colors outside ``[0, max_colors)``
    are masked out of the scatter-add (JAX's default clip mode would silently
    inflate the ``max_colors - 1`` class instead) and surfaced in the global
    ``n_out_of_range`` count so a poisoned view is visible in the stats.
    """
    valid = jnp.arange(n_local_max) < n_local
    raw = view[:n_local_max]
    in_range = (raw >= 0) & (raw < max_colors)
    oor = comm.psum(jnp.sum(valid & ~in_range, dtype=jnp.int32))
    counted = valid & in_range
    idx = jnp.where(counted, raw, 0)
    local = jnp.zeros((max_colors,), jnp.int32).at[idx].add(
        counted.astype(jnp.int32))
    local = local.at[0].set(0)
    return comm.psum(local), oor


def permutation_rank(sizes, kind: str, key) -> jnp.ndarray:
    """rank[c] = recoloring step (1-based) of color class c; 0 for class 0.

    Empty classes sort to the back (their steps are no-ops past K).
    """
    mc = sizes.shape[0]
    colors = jnp.arange(mc, dtype=jnp.int32)
    present = (sizes > 0) & (colors > 0)
    big = jnp.iinfo(jnp.int32).max
    if kind == RV:
        key_v = jnp.where(present, -colors, big)
    elif kind == NI:
        key_v = jnp.where(present, -sizes, big)
    elif kind == ND:
        key_v = jnp.where(present, sizes, big)
    elif kind == RAND:
        r = jax.random.permutation(key, mc).astype(jnp.int32)
        key_v = jnp.where(present, r, big)
    else:
        raise ValueError(f"unknown permutation {kind!r}")
    # lexsort: primary = key_v, tie-break = color id (stable, overflow-free)
    order = jnp.lexsort((colors, key_v))             # colors by visit step
    rank = jnp.zeros((mc,), jnp.int32).at[order].set(
        jnp.arange(1, mc + 1, dtype=jnp.int32))
    return jnp.where(present, rank, 0).astype(jnp.int32)


def permutation_rank_traced(sizes, kind_id, key) -> jnp.ndarray:
    """``permutation_rank`` with the kind resolved as a traced branch.

    ``kind_id`` indexes ``ALL_PERMS`` (see ``PERM_IDS``); each branch is the
    static function above, so a branch is bitwise-identical to the same call
    with a static kind — the fused pipeline's schedule can live in one jitted
    program without re-tracing per permutation kind.
    """
    branches = [lambda s, ky, k=k: permutation_rank(s, k, ky)
                for k in ALL_PERMS]
    return jax.lax.switch(kind_id, branches, sizes, key)


def _cross_deps(step_of, arrs, n_local_max):
    """Per cross edge: (dep mask, reader step s_v, ghost index of the writer).

    A dependency exists where the local reader (step ``s_v``) reads a ghost
    whose writer recolors at an earlier step ``s_u``; an exchange of that
    pair must then happen in ``[s_u, s_v-1]`` — the just-in-time choice is
    ``s_v - 1``, letting every pending color piggyback.
    """
    src, dst = arrs["edge_src"], arrs["indices"]
    step_rows = jnp.concatenate(
        [step_of[:n_local_max], jnp.zeros((1,), step_of.dtype)])
    s_v = step_rows[src]
    s_u = step_of[dst]
    is_ghost = (dst >= n_local_max) & (dst < step_of.shape[0] - 1)
    dep = is_ghost & (s_u > 0) & (s_v > s_u)
    return dep, s_v, jnp.maximum(dst - n_local_max, 0)


def _cross_deps_ell(step_of, nbr2, n_local_max):
    """Cross deps over the flattened two-hop ELL rows (distance=2 readers).

    A D2 reader also consumes its two-hop ghosts' colors, so those pairs
    constrain the piggyback schedule exactly like the CSR cross edges; padded
    entries point at the sentinel (step 0) and never form a dependency.
    """
    dst = nbr2.reshape(-1)
    s_v = jnp.repeat(step_of[:n_local_max], nbr2.shape[1])
    s_u = step_of[dst]
    is_ghost = (dst >= n_local_max) & (dst < step_of.shape[0] - 1)
    dep = is_ghost & (s_u > 0) & (s_v > s_u)
    return dep, s_v, jnp.maximum(dst - n_local_max, 0)


def _dep_sources(step_of, arrs, n_local_max, distance):
    """All (dep, s_v, ghost index) contributions the piggyback schedule sees."""
    deps = [_cross_deps(step_of, arrs, n_local_max)]
    if distance == 2:
        deps.append(_cross_deps_ell(step_of, arrs["nbr2"], n_local_max))
    return deps


def _needed_exchanges(step_of, arrs, n_local_max: int, K, max_colors: int,
                      comm: AxisComm, piggyback: bool, distance: int = 1):
    """The piggybacking schedule: needed[t] = exchange event after step t.

    Entry K is the end-of-iteration exchange (always on).
    """
    # contract: K is the class count, psum-derived by every caller
    # (class_sizes), so the exchange schedule is shard-agreed
    K = shard_uniform(K)
    if piggyback:
        needed = jnp.zeros((max_colors + 1,), bool)
        for dep, s_v, _ in _dep_sources(step_of, arrs, n_local_max, distance):
            idx = jnp.where(dep, s_v - 1, 0)
            needed = needed.at[idx].max(dep)
        needed = needed.at[0].set(False)
        needed = comm.pmax(needed)                   # pre-communication
    else:
        needed = jnp.arange(max_colors + 1) <= K     # exchange every step
    needed = needed.at[max_colors].set(True)
    return needed


def _needed_exchange_rounds(step_of, arrs, n_local_max: int, K,
                            max_colors: int, comm: AxisComm, piggyback: bool,
                            P_size: int, n_rounds: int, distance: int = 1):
    """Sparse piggybacking: needed[t, r] = ``ppermute`` round r after step t.

    The paper's pre-communication ("who receives at which step") refined per
    *link*: each dependency marks only the ring shift of its writer's owner,
    so an exchange event ships only the rounds some destination still needs.
    At ``distance=2`` the two-hop ELL rows contribute dependencies too.  Row
    ``max_colors`` (end of iteration) runs every round — it leaves all
    ghosts fresh for the next iteration.
    """
    K = shard_uniform(K)             # same contract as _needed_exchanges
    if piggyback:
        needed = jnp.zeros((max_colors + 1, max(n_rounds, 1)), bool)
        for dep, s_v, gi in _dep_sources(step_of, arrs, n_local_max, distance):
            shift = (comm.index() - arrs["ghost_owner"][gi]) % P_size
            rnd = arrs["shift_to_round"][shift]      # >= 0 wherever dep holds
            idx = jnp.where(dep, s_v - 1, 0)
            rdx = jnp.where(dep, rnd, 0)
            needed = needed.at[idx, rdx].max(dep)
        needed = needed[:, :n_rounds]
        needed = needed.at[0].set(False)
        needed = comm.pmax(needed)                   # pre-communication
    else:
        needed = jnp.broadcast_to(
            (jnp.arange(max_colors + 1) <= K)[:, None],
            (max_colors + 1, n_rounds))
    needed = needed.at[max_colors].set(True)
    return needed


def recolor_pass_spmd(arrs, view, rank, n_classes, cfg: RecolorConfig,
                      P_size: int | None = None, plan_static=None,
                      axis: str = AXIS, lane_axes: tuple = ()):
    """One synchronous recoloring iteration given a precomputed class rank.

    The shared core of ``recolor_spmd`` (static permutation kind) and the
    fused ``pipeline.color_then_recolor`` loop (kind resolved as a traced
    branch): everything from the step map through the chunked hot loop.

    Hot loop: vertices are sorted by class step; each class is consumed as
    <= ceil(pmax(class size)/chunk) fixed-size chunks.  A chunk gathers its
    ELL neighbour rows, gathers their current colors, and first-fit-colors
    the whole chunk at once through ``kernels.ops.select_colors`` — no dense
    occupancy, no scatter over the edge list.  Chunk order within a class is
    irrelevant (a class is an independent set), and the chunk schedule is
    identical on every shard, so collectives stay uniform.

    Exchanges route through ``comm.make_exchange``; under the sparse scheme
    the piggyback schedule additionally masks *which ppermute rounds* each
    exchange event ships (``_needed_exchange_rounds``) — a link with nothing
    pending costs nothing.  ``P_size``/``plan_static`` are required for the
    sparse scheme (the drivers thread them automatically).

    ``lane_axes`` (2D ``batch × shard`` meshes, DESIGN.md §10): graph lanes
    on different batch rows have different class counts and piggyback
    schedules, so the chunk trip count and every exchange gate widen to the
    lane-uniform union (``AxisComm.lane_uniform``) — every device executes
    the same collective sequence — while each lane applies ghost refreshes
    and byte accounting under its *own* schedule, keeping per-lane results
    bitwise the solo run's.
    """
    comm = AxisComm(axis, lane_axes)
    # contract: callers derive n_classes from psum-reduced class sizes, so
    # the per-class chunk schedule (and with it every exchange event) is
    # identical on all shards
    n_classes = shard_uniform(n_classes)
    n_local_max = arrs["indptr"].shape[0] - 1
    n_slots = arrs["prio"].shape[0]
    n_local = arrs["n_local"]
    nbr = arrs["nbr"]
    mc = cfg.max_colors
    # chunk size is bitwise-invariant (within-class chunks never interact:
    # a class is an independent set, so no chunk reads another's writes),
    # so clamp it to the row count — a chunk wider than the shard's vertex
    # range would gather pure padding every class step, which dominates the
    # runtime of small graphs (and of every lane of the batched pipeline).
    chunk = min(cfg.chunk, n_local_max)
    if cfg.scheme == AUTO:
        raise ValueError("scheme='auto' must be resolved by a driver "
                         "(resolve_cfg / resolve_scheme) before the SPMD fn")
    sparse = cfg.scheme == SPARSE
    if sparse and (P_size is None or plan_static is None):
        raise ValueError("sparse scheme needs P_size and plan_static "
                         "(see PartitionedGraph.comm_plan)")
    if cfg.distance == 2 and "nbr2" not in arrs:
        raise ValueError("distance=2 needs the two-hop halo: partition with "
                         "partition_graph(g, P, halo=2)")

    step_of = rank[view]                              # (n_slots,) step per slot
    step_of = step_of.at[n_slots - 1].set(0)          # sentinel

    if sparse:
        n_rounds = len(plan_static[0])
        needed_rounds = _needed_exchange_rounds(
            step_of, arrs, n_local_max, n_classes, mc, comm, cfg.piggyback,
            P_size, n_rounds, cfg.distance)
        # event bitmap = any round pending (one dep scan + pmax, not two);
        # entry mc stays on so event counting matches the broadcast scheme
        needed = needed_rounds.any(axis=1).at[mc].set(True)
    else:
        needed = _needed_exchanges(step_of, arrs, n_local_max, n_classes, mc,
                                   comm, cfg.piggyback, cfg.distance)

    exchange = make_exchange(arrs, n_local_max, P_size, comm,
                             cfg.comm_config, plan_static)

    valid_local = jnp.arange(n_local_max) < n_local
    step_loc = step_of[:n_local_max]

    # Step-sorted visit order + per-class chunk schedule.  rank values of
    # present classes are contiguous 1..n_classes, so classes t=1..n_classes
    # each get >= 1 chunk (pmax over shards keeps the trip count uniform).
    sort_key = jnp.where(valid_local, step_loc, jnp.int32(mc + 1))
    sorted_rows = jnp.argsort(sort_key).astype(jnp.int32)
    sorted_pad = jnp.concatenate([sorted_rows, jnp.zeros((chunk,), jnp.int32)])
    local_sizes = jnp.zeros((mc + 2,), jnp.int32).at[sort_key].add(1)[:mc + 1]
    start_local = jnp.cumsum(local_sizes) - local_sizes   # exclusive cumsum
    max_sizes = comm.pmax(local_sizes)
    chunks_per_class = (max_sizes + chunk - 1) // chunk
    t_arange = jnp.arange(mc + 1)
    chunks_per_class = jnp.where(
        (t_arange >= 1) & (t_arange <= n_classes),
        jnp.maximum(chunks_per_class, 1), 0)
    cum = jnp.cumsum(chunks_per_class)     # cum[t] = chunks through class t

    def chunk_body(ci, carry):
        new_view, n_ex, n_bytes = carry
        t = jnp.searchsorted(cum, ci, side="right").astype(jnp.int32)
        j = ci - (cum[t] - chunks_per_class[t])          # chunk # within class
        pos = start_local[t] + j * chunk
        active = jnp.arange(chunk, dtype=jnp.int32) < local_sizes[t] - j * chunk
        rows = jax.lax.dynamic_slice(sorted_pad, (pos,), (chunk,))
        rows = jnp.where(active, rows, 0)
        nbr_colors = new_view[nbr[rows]]                 # (chunk, maxd) gather
        if cfg.distance == 2:
            colors = ops.select_colors_d2(
                nbr_colors, new_view[arrs["nbr2"][rows]], active,
                max_colors=mc, selection=ops.FIRST_FIT, backend=cfg.backend)
        else:
            colors = ops.select_colors(nbr_colors, active, max_colors=mc,
                                       selection=ops.FIRST_FIT,
                                       backend=cfg.backend)
        idx = jnp.where(active, rows, n_slots - 1)       # park writes on the
        val = jnp.where(active, colors, 0)               # sentinel (stays 0)
        new_view = new_view.at[idx].set(val.astype(new_view.dtype))
        is_last = (ci + 1) == cum[t]
        is_end = t == n_classes
        do_ex = is_last & (needed[jnp.minimum(t, mc)] | is_end)
        # execute under the lane-uniform gate, apply under the lane's own:
        # a batch-row peer's exchange event must run here too (same
        # ppermute sequence mesh-wide), but this lane's ghosts only
        # refresh on its own schedule — early refreshes would de-stale
        # ghost colors the solo run still reads old
        go_ex = comm.lane_uniform(do_ex)
        if sparse:
            mask = (needed_rounds[jnp.minimum(t, mc)] | is_end) & do_ex
            ex = lambda v: exchange(v, round_mask=comm.lane_uniform(mask),
                                    apply_mask=mask)
        else:
            ex = exchange
        ex_view, b = jax.lax.cond(go_ex, ex,
                                  lambda v: (v, jnp.int32(0)), new_view)
        new_view = jnp.where(do_ex, ex_view, new_view)
        return (new_view, n_ex + do_ex.astype(jnp.int32),
                n_bytes + jnp.where(do_ex, b, 0))

    new_view0 = jnp.zeros((n_slots,), jnp.int32)
    # mesh-wide trip count: chunks past this lane's cum[mc] visit no active
    # rows (and never gate an exchange), so they are exact no-ops
    new_view, n_ex, n_bytes = jax.lax.fori_loop(
        0, comm.lane_uniform(cum[mc]), chunk_body,
        (new_view0, jnp.int32(0), jnp.int32(0)))

    local_max = jnp.max(jnp.where(valid_local, new_view[:n_local_max], 0))
    stats = dict(
        n_colors=comm.pmax(local_max),
        n_colors_before=n_classes,
        n_exchanges=n_ex,
        n_steps=n_classes,
        wire_bytes=n_bytes,
    )
    return new_view, stats


def recolor_spmd(arrs, view, key, perm_kind: str, cfg: RecolorConfig,
                 P_size: int | None = None, plan_static=None,
                 axis: str = AXIS):
    """One synchronous recoloring iteration (per-shard SPMD).

    `view` is a valid coloring (n_slots,) with fresh ghosts. Returns the new
    view plus stats (colors, executed/possible exchanges, wire bytes); the
    step loop itself lives in ``recolor_pass_spmd``.  The fused pipeline
    threads the post-iteration ``class_sizes`` into the next iteration
    instead of recomputing it (bitwise the same array) — here the stand-alone
    call computes both ends itself.
    """
    comm = AxisComm(axis)
    n_local_max = arrs["indptr"].shape[0] - 1
    sizes, n_oor = class_sizes(view, arrs["n_local"], n_local_max,
                               cfg.max_colors, comm)
    n_classes = jnp.sum(sizes > 0).astype(jnp.int32)
    rank = permutation_rank(sizes, perm_kind, key)
    new_view, stats = recolor_pass_spmd(arrs, view, rank, n_classes, cfg,
                                        P_size=P_size, plan_static=plan_static,
                                        axis=axis)
    sizes_after, _ = class_sizes(new_view, arrs["n_local"], n_local_max,
                                 cfg.max_colors, comm)
    # distinct classes actually in use — the paper's quality metric (the max
    # id in ``n_colors`` can overstate it once recoloring empties classes);
    # also the fused pipeline's adaptive-stop signal
    stats["n_colors_distinct"] = jnp.sum(sizes_after > 0).astype(jnp.int32)
    stats["n_out_of_range"] = n_oor
    return new_view, stats


def arc_order_spmd(view, n_local, n_local_max, rank):
    """aRC visit order: local slots sorted by (class step, slot) — per shard."""
    step_loc = rank[view[:n_local_max]]
    valid = jnp.arange(n_local_max) < n_local
    big = jnp.iinfo(jnp.int32).max
    key_v = jnp.where(valid, step_loc, big)
    slots = jnp.lexsort((jnp.arange(n_local_max, dtype=jnp.int32),
                         key_v)).astype(jnp.int32)
    return jnp.where(key_v[slots] < big, slots, -1)


def arc_spmd(arrs, view, key, perm_kind: str, rc_cfg: RecolorConfig,
             sp_cfg: ColorConfig, P_size: int | None = None,
             plan_static=None, axis: str = AXIS):
    """One asynchronous recoloring iteration: local class order + speculative."""
    comm = AxisComm(axis)
    n_local_max = arrs["indptr"].shape[0] - 1
    mc = rc_cfg.max_colors
    sizes, n_oor = class_sizes(view, arrs["n_local"], n_local_max, mc, comm)
    # independent streams: the class permutation and the speculative repair
    # must not consume the same key (identical bits would correlate the RAND
    # permutation with the tie-break randomness)
    k_rank, k_repair = jax.random.split(key)
    rank = permutation_rank(sizes, perm_kind, k_rank)
    order = arc_order_spmd(view, arrs["n_local"], n_local_max, rank)
    new_view, stats = color_spmd(arrs, order, k_repair, sp_cfg, P_size=P_size,
                                 plan_static=plan_static, axis=axis)
    stats["n_out_of_range"] = n_oor
    return new_view, stats


# ----------------------------------------------------------------- drivers --

@lru_cache(maxsize=64)
def _rc_sim_fn(P, perm_kind, cfg, plan_static):
    fn = partial(recolor_spmd, perm_kind=perm_kind, cfg=cfg, P_size=P,
                 plan_static=plan_static)
    return jax.jit(lambda arrs, view, key: run_sim(fn, P, (arrs, view), (key,)))


def recolor_sim(pg: PartitionedGraph, view, perm_kind: str,
                cfg: RecolorConfig, key=None):
    """One synchronous RC iteration, simulated on one device.

    ``view`` — ``(P, n_slots)`` valid coloring with fresh ghosts (a driver
    output); ``perm_kind`` — one of ``RV``/``NI``/``ND``/``RAND``; ``key``
    defaults to a per-call-counter fold of ``cfg.seed`` (pass an explicit
    key for reproducible RAND permutations).  Returns ``(view, stats)``
    with python-int stats: ``n_colors`` (max id), ``n_colors_distinct``,
    ``n_colors_before``, ``n_exchanges`` (executed), ``n_steps`` (= class
    count), ``wire_bytes``, ``n_out_of_range``.  ``recolor_sharded`` is
    the bitwise-identical ``workers``-mesh variant.
    """
    cfg = resolve_cfg(pg, cfg)
    arrs = {k: jnp.asarray(v) for k, v in
            pg.arrays(sparse=cfg.scheme == SPARSE).items()}
    if key is None:
        key = _default_key(cfg.seed)
    new_view, stats = _rc_sim_fn(pg.P, perm_kind, cfg, _plan_static(pg, cfg))(
        arrs, jnp.asarray(view), key)
    return new_view, stats_to_host(stats)


@lru_cache(maxsize=64)
def _arc_sim_fn(P, perm_kind, rc_cfg, sp_cfg, plan_static):
    fn = partial(arc_spmd, perm_kind=perm_kind, rc_cfg=rc_cfg, sp_cfg=sp_cfg,
                 P_size=P, plan_static=plan_static)
    return jax.jit(lambda arrs, view, key: run_sim(fn, P, (arrs, view), (key,)))


def arc_sim(pg: PartitionedGraph, view, perm_kind: str, rc_cfg: RecolorConfig,
            sp_cfg: ColorConfig, key=None):
    """One asynchronous (aRC) iteration, simulated: order by local class
    rank (``rc_cfg``, ``perm_kind``) and rerun the speculative framework
    (``sp_cfg``) — conflicts possible, hence the repair rounds.  Shapes and
    stats as ``color_graph_sim``; the key splits into independent rank and
    repair streams.
    """
    rc_cfg, sp_cfg = resolve_cfg(pg, rc_cfg), resolve_cfg(pg, sp_cfg)
    arrs = {k: jnp.asarray(v) for k, v in
            pg.arrays(sparse=sp_cfg.scheme == SPARSE).items()}
    if key is None:
        key = _default_key(rc_cfg.seed)
    new_view, stats = _arc_sim_fn(pg.P, perm_kind, rc_cfg, sp_cfg,
                                  _plan_static(pg, sp_cfg))(
        arrs, jnp.asarray(view), key)
    return new_view, stats_to_host(stats)


def recolor_sharded(pg: PartitionedGraph, view, perm_kind: str,
                    cfg: RecolorConfig, mesh, key=None):
    """``recolor_sim`` on a real mesh shard axis (``shard_axis_of(mesh)``;
    same contract, bitwise-identical results)."""
    cfg = resolve_cfg(pg, cfg)
    arrs = {k: jnp.asarray(v) for k, v in
            pg.arrays(sparse=cfg.scheme == SPARSE).items()}
    if key is None:
        key = _default_key(cfg.seed)
    axis = shard_axis_of(mesh)
    fn = partial(recolor_spmd, perm_kind=perm_kind, cfg=cfg, P_size=pg.P,
                 plan_static=_plan_static(pg, cfg), axis=axis)
    new_view, stats = jax.jit(
        lambda a, v, k: run_sharded(fn, mesh, (a, v), (k,), axis=axis))(
            arrs, jnp.asarray(view), key)
    return new_view, stats_to_host(stats)


def schedule_for_iteration(it: int, base: str = ND, rand_every: int = 0,
                           rand_pow2: bool = False) -> str:
    """Permutation for iteration `it` (1-based): ND-RAND%x / ND-RAND%2^i."""
    if rand_pow2:
        return RAND if it & (it - 1) == 0 and it > 1 else base
    if rand_every and it % rand_every == 0:
        return RAND
    return base


def recolor_iterations(pg: PartitionedGraph, view, n_iters: int,
                       cfg: RecolorConfig, *, base_perm: str = ND,
                       rand_every: int = 0, rand_pow2: bool = False,
                       seed: int = 0, collect=None, fused: bool = True):
    """Run `n_iters` RC iterations with an ND-RAND%x style schedule (sim).

    By default the loop runs *device-resident* through the fused pipeline
    (``pipeline.recolor_loop_sim``): one jitted program, no per-iteration
    host round-trip, bitwise-identical views and history to the host loop.
    ``fused=False`` forces the host loop (one ``recolor_sim`` dispatch per
    iteration) — kept as the reference the fused path is tested against;
    ``collect=`` implies it, since per-iteration views must reach the host.
    """
    if fused and collect is None and n_iters > 0:
        from .pipeline import PipelineConfig, recolor_loop_sim
        pcfg = PipelineConfig(
            color=None, recolor=cfg, n_iters=n_iters, base_perm=base_perm,
            rand_every=rand_every, rand_pow2=rand_pow2, seed=seed)
        view, history, _ = recolor_loop_sim(pg, view, pcfg)
        return view, history
    history = []
    for it in range(1, n_iters + 1):
        kind = schedule_for_iteration(it, base_perm, rand_every, rand_pow2)
        key = jax.random.fold_in(jax.random.key(seed), it)
        view, stats = recolor_sim(pg, view, kind, cfg, key)
        stats["iteration"], stats["perm"] = it, kind
        history.append(stats)
        if collect is not None:
            collect(view, stats)
    return view, history
