"""Speculative greedy distributed coloring (Bozdağ et al. framework, §2.2).

Round structure (all inside one jitted SPMD function):

  while conflicts remain:
    compact uncolored vertices to the front of the visit order
    for each superstep chunk of `superstep` vertices:
        color the chunk (local view, possibly stale ghosts) — see below
        exchange boundary colors (every `exchange_every` supersteps; =1 is the
        paper's synchronous variant, >1 models asynchronous staleness)
    final boundary exchange
    detect conflicts over the round's *frontier* (the vertices colored this
    round — see below); the lower-priority endpoint is uncolored and queued
    for the next round (random total order tie-break)

Chunk coloring has two modes (``ColorConfig.parallel_chunk``):

  parallel (default) — the whole superstep tile colors at once against the
    stale view: one ELL gather of neighbour colors, then tile-parallel bitset
    selection through ``kernels.ops.select_colors`` (Pallas on TPU).  Vertices
    inside one chunk cannot see each other, so same-chunk neighbours may
    conflict — that is *legal* in the speculative framework, and the existing
    round loop repairs it (the highest-priority endpoint always survives, so
    every round makes progress).  This is the bulk-synchronous shape of
    Bogle & Slota / Rokos et al. and the fast path on wide SIMD hardware.
  sequential — the paper-faithful scalar loop: one vertex at a time inside the
    chunk, each seeing all earlier in-chunk colors (conflicts only ever
    involve boundary vertices).  Also used for Least-Used selection, whose
    running usage histogram is inherently sequential.

Communication scaling (this file + comm.py/graph.py, DESIGN.md §2):

- exchanges route through ``comm.make_exchange`` — the broadcast all-gather
  or the sparse per-neighbour ``ppermute`` schedule (``ColorConfig.scheme``),
  bitwise-identical colorings either way, measured wire bytes in the stats;
- *no-op exchange elision*: an exchange whose payloads cannot have changed
  (no shard colored a boundary vertex since the last exchange, pmax-agreed)
  is skipped.  With an interior-first visit order
  (``ordering.INTERNAL_FIRST``) the supersteps covering the interior prefix
  therefore perform no communication at all.  Skipping a no-op exchange is
  bitwise-safe: ghost values could not have changed;
- conflict detection and repair shrink to the *conflict frontier*: rounds
  after the first only rescan the vertices recolored this round (chunked,
  trip count pmax-reduced) instead of all of ``n_local_max``.  Conflicts can
  only involve this round's frontier — older colors were mutually repaired
  at the previous round's detection, and a fresh vertex always sees every
  older neighbour color (local ones directly, remote ones from the round's
  exchanges) — and in the paper's sequential mode the frontier after round 0
  is further contained in the boundary set.

The same function serves initial coloring (any order, any selection strategy
incl. Random-X Fit) and the aRC second pass (order derived from a previous
coloring's classes).

Distance-2 mode (``ColorConfig(distance=2)``, DESIGN.md §5): on a halo=2
partition the selection ORs the one-hop and two-hop forbidden bitsets
(``ops.select_colors_d2``) and conflict detection scans both ELL tiles; the
round/repair structure is unchanged.  ``partial=True`` + ``marked=`` on the
drivers colors only a marked subset (bipartite partial D2 coloring) —
unmarked vertices stay at color 0, invisible to every bitset.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from . import selection as sel
from .comm import (AUTO, AXIS, DEFAULT_SCHEME, SCHEME_CHOICES, SCHEMES,
                   SPARSE, AxisComm, CommConfig, make_exchange, resolve_scheme,
                   run_sharded, run_sim, shard_axis_of, stats_to_host)
from .graph import PartitionedGraph


def validate_color_bounds(max_colors: int, wire16: bool, backend: str):
    """Shared config guards for ColorConfig / RecolorConfig."""
    assert max_colors % 32 == 0, "max_colors must be 32-aligned"
    if wire16:
        # int16 wire payloads carry the color value itself; anything past
        # int16 range would silently alias colors after the exchange.
        assert max_colors <= 32767, (
            f"wire16 carries colors as int16; max_colors="
            f"{max_colors} exceeds 32767")
    assert backend in ops.BACKENDS, f"bad backend {backend!r}"


@dataclasses.dataclass(frozen=True)
class ColorConfig:
    """Static configuration of one distributed coloring run.

    Units: ``superstep`` and ``tile`` are vertex counts per chunk (both
    clamped to the shard's row count at trace time); ``max_colors`` is the
    color-id bound (32-aligned — the bitset word width); ``exchange_every``
    counts supersteps between boundary exchanges; ``max_rounds`` bounds the
    speculate/repair rounds.  Drivers: ``color_graph_sim`` (one device, P
    vmap lanes) and ``color_graph_sharded`` (real ``workers`` mesh axis)
    run the identical program; ``color_spmd`` is the raw per-shard SPMD
    function both wrap.
    """

    max_colors: int = 1024
    superstep: int = 512           # paper's superstep size (vertices per chunk)
    selection: str = sel.FIRST_FIT
    random_x: int = 10             # X for Random-X Fit
    stagger_estimate: int = 64     # initial color estimate for Staggered FF
    exchange_every: int = 1        # 1 = synchronous; k>1 = bounded staleness
    max_rounds: int = 64
    scheme: str = DEFAULT_SCHEME   # boundary exchange: "sparse" | "allgather"
                                   # | "auto" (pick by modeled bytes at trace
                                   # time; default follows $REPRO_SCHEME)
    wire16: bool = False           # int16 boundary payloads (half ICI bytes)
    parallel_chunk: bool = True    # tile-parallel supersteps (False = paper's
                                   # sequential scalar loop, bitwise-preserved)
    tile: int = 128                # vertices colored simultaneously within a
                                   # superstep; bounds speculative conflicts
                                   # while `superstep` keeps the comm cadence
    backend: str = "auto"          # kernels.ops backend: auto | xla | pallas
    distance: int = 1              # 1 = proper coloring; 2 = distance-2
                                   # (needs a halo=2 PartitionedGraph)
    partial: bool = False          # color only a marked vertex subset
                                   # (drivers take ``marked=``; bipartite
                                   # partial D2 coloring of Taş et al.)
    seed: int = 0

    def __post_init__(self):
        validate_color_bounds(self.max_colors, self.wire16, self.backend)
        assert self.scheme in SCHEME_CHOICES, f"bad scheme {self.scheme!r}"
        assert self.tile > 0
        assert self.distance in (1, 2), f"bad distance {self.distance}"

    @property
    def n_words(self) -> int:
        return self.max_colors // 32

    @property
    def comm_config(self) -> CommConfig:
        return CommConfig(scheme=self.scheme, wire16=self.wire16)

    @property
    def use_parallel_chunk(self) -> bool:
        """Least-Used chases a running histogram -> stays sequential."""
        return self.parallel_chunk and self.selection != sel.LEAST_USED

    def stagger_offset(self, p_idx):
        """Staggered First Fit start color of processor ``p_idx``."""
        return (p_idx * self.stagger_estimate) % self.max_colors


def _forbidden_words(view, indptr, indices, v, n_words):
    """Bitset of neighbour colors of local vertex `v` under current view."""
    words = jnp.zeros((n_words,), dtype=jnp.uint32).at[0].set(jnp.uint32(1))

    def body(e, words):
        return sel.set_bit(words, view[indices[e]])

    return jax.lax.fori_loop(indptr[v], indptr[v + 1], body, words)


def _forbid_ell_row(view, row, words):
    """OR the colors along one sentinel-padded ELL row into the bitset.

    Padding points at the sentinel slot (color 0 = bit 0, always set), so no
    masking is needed — used for the two-hop row in the sequential D2 path.
    """
    def body(k, words):
        return sel.set_bit(words, view[row[k]])

    return jax.lax.fori_loop(0, row.shape[0], body, words)


def _pick_color(words, usage, v_rand, p_idx, cfg: ColorConfig):
    if cfg.selection == sel.FIRST_FIT:
        return sel.first_fit(words)
    if cfg.selection == sel.STAGGERED:
        return sel.staggered(words, cfg.stagger_offset(p_idx))
    if cfg.selection == sel.LEAST_USED:
        return sel.least_used(words, usage)
    if cfg.selection == sel.RANDOM_X:
        return sel.random_x(words, cfg.random_x, v_rand)
    raise ValueError(f"unknown selection {cfg.selection!r}")


def _greedy_chunk(view, usage, order, rand_u32, start, count, arrs, p_idx,
                  cfg: ColorConfig):
    """Sequentially color `order[start:start+count]` (the superstep body)."""
    indptr, indices = arrs["indptr"], arrs["indices"]

    def body(i, carry):
        view, usage = carry
        v = order[i]
        v_safe = jnp.maximum(v, 0)
        needs = (v >= 0) & (view[v_safe] == 0)

        def color_one(args):
            view, usage = args
            words = _forbidden_words(view, indptr, indices, v_safe, cfg.n_words)
            if cfg.distance == 2:
                words = _forbid_ell_row(view, arrs["nbr2"][v_safe], words)
            c = _pick_color(words, usage, rand_u32[v_safe], p_idx, cfg)
            c = jnp.minimum(c, cfg.max_colors - 1).astype(jnp.int32)
            return view.at[v_safe].set(c), usage.at[c].add(1)

        return jax.lax.cond(needs, color_one, lambda a: a, (view, usage))

    return jax.lax.fori_loop(start, start + count, body, (view, usage))


def _parallel_chunk(view, usage, order_pad, rand_u32, start, arrs, p_idx,
                    cfg: ColorConfig, superstep: int):
    """Color one superstep as tile-parallel sub-tiles against the stale view.

    Each sub-tile of ``cfg.tile`` vertices colors at once: one ELL-row gather
    + one bitset selection through ``kernels.ops.select_colors``.  The view
    updates between sub-tiles (so speculative conflicts stay bounded by the
    tile width), while boundary exchanges keep the ``superstep`` cadence —
    the tile is a hardware knob, the superstep the paper's comm knob.
    Conflicts within a tile are repaired by the round loop.  ``order_pad`` is
    the visit order padded by ``superstep`` entries of -1 so slices never
    clamp into unvisited territory.
    """
    n_slots = view.shape[0]
    tile = min(cfg.tile, superstep)
    n_tiles = -(-superstep // tile)
    offset = cfg.stagger_offset(p_idx)

    def tile_body(ti, carry):
        view, usage = carry
        chunk = jax.lax.dynamic_slice(order_pad, (start + ti * tile,), (tile,))
        v_safe = jnp.maximum(chunk, 0)
        active = (chunk >= 0) & (view[v_safe] == 0)
        nbr_colors = view[arrs["nbr"][v_safe]]       # (tile, maxd)
        if cfg.distance == 2:
            colors = ops.select_colors_d2(
                nbr_colors, view[arrs["nbr2"][v_safe]], active,
                rand_u32[v_safe], max_colors=cfg.max_colors,
                selection=cfg.selection, x=cfg.random_x, offset=offset,
                backend=cfg.backend)
        else:
            colors = ops.select_colors(
                nbr_colors, active, rand_u32[v_safe],
                max_colors=cfg.max_colors, selection=cfg.selection,
                x=cfg.random_x, offset=offset, backend=cfg.backend)
        colors = jnp.minimum(colors, cfg.max_colors - 1).astype(jnp.int32)
        idx = jnp.where(active, v_safe, n_slots - 1)   # park writes on the
        val = jnp.where(active, colors, 0)             # sentinel (stays 0)
        view = view.at[idx].set(val.astype(view.dtype))
        usage = usage.at[jnp.where(active, colors, 0)].add(
            active.astype(jnp.int32))
        return view, usage

    return jax.lax.fori_loop(0, n_tiles, tile_body, (view, usage))


def _detect_conflicts_frontier(view, arrs, order_pad, n_steps, n_need,
                               superstep: int, backend="auto",
                               distance: int = 1):
    """Uncolor the lower-priority endpoint of every same-color frontier edge.

    Chunked over the round's visit order: only the ``n_need`` vertices
    recolored this round are rescanned (``n_steps`` is pmax-reduced by the
    caller, so the trip count is shard-uniform and *shrinks* with the
    conflict frontier).  Every chunk reads the same pre-detection ``view`` —
    identical results to one full-width pass — and writes uncolorings into a
    separate copy.  ``distance=2`` additionally scans the two-hop ELL rows
    (both endpoints of a distance-2 conflict list each other in ``nbr2``, so
    the repair argument is unchanged).  Returns (new_view, n_conflicts,
    any_boundary_conflict).
    """
    nbr, prio, is_internal = arrs["nbr"], arrs["prio"], arrs["is_internal"]
    n_slots = view.shape[0]

    def body(si, carry):
        new_view, n_conf, bnd = carry
        rows = jax.lax.dynamic_slice(order_pad, (si * superstep,),
                                     (superstep,))
        pos = si * superstep + jnp.arange(superstep, dtype=jnp.int32)
        active = (rows >= 0) & (pos < n_need)
        r_safe = jnp.maximum(rows, 0)
        if distance == 2:
            nbr2 = arrs["nbr2"]
            conf = ops.detect_conflicts_d2(
                view[r_safe], prio[r_safe], view[nbr[r_safe]],
                prio[nbr[r_safe]], view[nbr2[r_safe]], prio[nbr2[r_safe]],
                active, backend=backend)
        else:
            conf = ops.detect_conflicts(view[r_safe], prio[r_safe],
                                        view[nbr[r_safe]], prio[nbr[r_safe]],
                                        active, backend=backend)
        idx = jnp.where(conf, r_safe, n_slots - 1)   # sentinel stays 0
        new_view = new_view.at[idx].set(0)
        n_conf = n_conf + jnp.sum(conf, dtype=jnp.int32)
        bnd = bnd | jnp.any(conf & ~is_internal[r_safe])
        return new_view, n_conf, bnd

    return jax.lax.fori_loop(
        0, n_steps, body, (view, jnp.int32(0), jnp.bool_(False)))


def _compact_order(order, view):
    """Stable-move still-uncolored vertices to the front of the visit order.

    Uncolored vertices are always contained in the previous round's frontier
    (detection only uncolors freshly-colored rows), so the compacted prefix
    — and with it every per-round trip count — shrinks monotonically.
    """
    v_safe = jnp.maximum(order, 0)
    needs = (order >= 0) & (view[v_safe] == 0)
    perm = jnp.argsort(~needs, stable=True)
    return order[perm], jnp.sum(needs, dtype=jnp.int32)


def color_spmd(arrs, order, key, cfg: ColorConfig, P_size: int | None = None,
               plan_static=None, axis: str = AXIS, lane_axes: tuple = ()):
    """Per-shard SPMD speculative coloring. Returns (view, stats dict).

    ``P_size``/``plan_static`` (``PartitionedGraph.comm_plan.static``) are
    required for the sparse exchange scheme — the ``ppermute`` round
    schedule is static; the drivers thread them automatically.  ``axis``
    names the shard mesh axis all collectives run over (``shard_axis_of``
    derives it from a mesh; defaults to ``"workers"``); ``lane_axes`` the
    batch mesh axes control flow must additionally be uniform over on a 2D
    ``batch × shard`` mesh (``AxisComm.lane_uniform``, DESIGN.md §10) —
    loop trip counts and exchange gates widen to the mesh-wide maximum
    while every lane masks the *application* with its own local predicate,
    so per-lane results (view, stats) stay bitwise the solo run's.
    """
    comm = AxisComm(axis, lane_axes)
    n_local_max = arrs["indptr"].shape[0] - 1
    n_slots = arrs["prio"].shape[0]
    p_idx = comm.index()
    if cfg.scheme == AUTO:
        raise ValueError("scheme='auto' must be resolved by a driver "
                         "(resolve_cfg / resolve_scheme) before the SPMD fn")
    if cfg.scheme == SPARSE and (P_size is None or plan_static is None):
        raise ValueError("sparse scheme needs P_size and plan_static "
                         "(see PartitionedGraph.comm_plan)")
    if cfg.distance == 2 and "nbr2" not in arrs:
        raise ValueError("distance=2 needs the two-hop halo: partition with "
                         "partition_graph(g, P, halo=2)")

    exchange = make_exchange(arrs, n_local_max, P_size, comm,
                             cfg.comm_config, plan_static)
    no_ex = lambda v: (v, jnp.int32(0))

    # Clamp the superstep (and, downstream, the tile) to the shard's row
    # count: every chunk/tile boundary at granularity >= n_local_max is
    # equivalent to one at n_local_max (a round is always a single step and
    # a single sub-tile covers every live vertex either way), so this is
    # bitwise-identical — it only stops small graphs (and every lane of the
    # batched pipeline) from gathering `superstep - n_local_max` rows of
    # pure padding per round.
    S = min(cfg.superstep, n_local_max)
    n_chunks_max = -(-n_local_max // S)
    view0 = jnp.zeros((n_slots,), jnp.int32)
    usage0 = jnp.zeros((cfg.max_colors,), jnp.int32)

    def round_body(state):
        view, usage, rnd, n_conf_in, n_ex, n_bytes, n_rnd = state
        order_r, n_need = _compact_order(order, view)
        n_need_max = comm.pmax(n_need)
        n_steps = (n_need_max + S - 1) // S
        # mesh-wide trip count: every batch lane executes the same number
        # of superstep chunks (chunks past a lane's own frontier only read
        # already-colored rows — the view[v] == 0 guard makes them no-ops)
        n_steps_all = (comm.lane_uniform(n_need_max) + S - 1) // S
        rkey = jax.random.fold_in(jax.random.fold_in(key, rnd), p_idx)
        rand_u32 = jax.random.bits(rkey, (n_slots,), jnp.uint32)
        order_pad = jnp.concatenate(
            [order_r, jnp.full((S,), -1, order_r.dtype)])

        # Which superstep chunks color at least one boundary vertex, on any
        # shard (one pmax per round).  Chunks of interior vertices cannot
        # change any exchange payload, so the exchanges they would trigger
        # are elided below — bitwise-safe, the ghosts could not move.
        pos = jnp.arange(n_chunks_max * S, dtype=jnp.int32)
        opad = order_pad[: n_chunks_max * S]
        bnd = ((opad >= 0) & (pos < n_need)
               & ~arrs["is_internal"][jnp.maximum(opad, 0)])
        chunk_bnd = comm.pmax(jnp.any(bnd.reshape(n_chunks_max, S), axis=1))

        def superstep(si, carry):
            view, usage, n_ex, n_bytes, pending = carry
            if cfg.use_parallel_chunk:
                view, usage = _parallel_chunk(view, usage, order_pad,
                                              rand_u32, si * S,
                                              arrs, p_idx, cfg, S)
            else:
                view, usage = _greedy_chunk(view, usage, order_r, rand_u32,
                                            si * S, S, arrs, p_idx, cfg)
            pending = pending | chunk_bnd[si]
            due = ((si + 1) % cfg.exchange_every == 0) | (si == n_steps - 1)
            do_ex = due & pending
            # execute under the lane-uniform gate (a lane never skips a
            # ppermute its batch-row peers run), apply under the lane's own
            new_view, b = jax.lax.cond(comm.lane_uniform(do_ex), exchange,
                                       no_ex, view)
            view = jnp.where(do_ex, new_view, view)
            return (view, usage, n_ex + do_ex.astype(jnp.int32),
                    n_bytes + jnp.where(do_ex, b, 0), pending & ~do_ex)

        view, usage, n_ex, n_bytes, _ = jax.lax.fori_loop(
            0, n_steps_all, superstep,
            (view, usage, n_ex, n_bytes, jnp.bool_(False)))
        view, n_conf, bnd_conf = _detect_conflicts_frontier(
            view, arrs, order_pad, n_steps, n_need, S, backend=cfg.backend,
            distance=cfg.distance)
        # publish uncolorings only if a boundary vertex lost somewhere
        do_final = comm.pmax(bnd_conf)
        new_view, b = jax.lax.cond(comm.lane_uniform(do_final), exchange,
                                   no_ex, view)
        view = jnp.where(do_final, new_view, view)
        n_conf = comm.psum(n_conf)
        # per-lane round count: a converged lane riding out its batch-row
        # peers' extra rounds (no-op bodies) must not count them
        return (view, usage, rnd + 1, n_conf,
                n_ex + do_final.astype(jnp.int32),
                n_bytes + jnp.where(do_final, b, 0),
                n_rnd + (n_conf_in > 0).astype(jnp.int32))

    def cond(state):
        _, _, rnd, n_conf, _, _, _ = state
        return comm.lane_uniform(n_conf > 0) & (rnd < cfg.max_rounds)

    state0 = (view0, usage0, jnp.int32(0), jnp.int32(1), jnp.int32(0),
              jnp.int32(0), jnp.int32(0))
    # round 0 must run: seed n_conf=1
    view, usage, _, _, n_ex, n_bytes, n_rounds = jax.lax.while_loop(
        cond, round_body, state0)

    local_max = jnp.max(view[:n_local_max])
    # distinct classes in use — the corrected quality metric (Staggered FF
    # spreads shards across the id range, so the max id alone can massively
    # overstate the color count); `usage` over-counts repaired vertices, so
    # derive the mask from the final view instead
    valid = jnp.arange(n_local_max) < arrs["n_local"]
    in_use = jnp.zeros((cfg.max_colors,), bool).at[
        jnp.where(valid, view[:n_local_max], 0)].max(valid)
    in_use = comm.pmax(in_use.at[0].set(False))
    stats = dict(
        n_colors=comm.pmax(local_max),
        n_colors_distinct=jnp.sum(in_use, dtype=jnp.int32),
        n_rounds=n_rounds,
        n_exchanges=n_ex,
        wire_bytes=n_bytes,
    )
    return view, stats


@lru_cache(maxsize=64)
def _sim_fn(P, cfg, plan_static):
    fn = partial(color_spmd, cfg=cfg, P_size=P, plan_static=plan_static)
    return jax.jit(lambda arrs, order, key: run_sim(fn, P, (arrs, order), (key,)))


def _plan_static(pg: PartitionedGraph, cfg) -> tuple | None:
    return pg.comm_plan.static if cfg.scheme == SPARSE else None


def resolve_cfg(pg: PartitionedGraph, cfg):
    """Concretize ``scheme="auto"`` against this partition's comm plan.

    Works on any frozen config dataclass with a ``scheme`` field
    (ColorConfig / RecolorConfig / PipelineConfig).  The decision is made
    from modeled bytes at trace time (``comm.resolve_scheme``); an explicit
    "sparse"/"allgather" passes through untouched, so the flag stays a
    user override.
    """
    if cfg.scheme == AUTO:
        cfg = dataclasses.replace(cfg, scheme=resolve_scheme(AUTO, pg))
    return cfg


def _apply_partial(order, cfg: ColorConfig, marked):
    """Mask the visit order down to the marked subset (``cfg.partial``).

    ``marked`` is a host-side (P, n_local_max) bool mask of local slots;
    unmarked vertices become ``-1`` entries (skipped everywhere), stay at
    color 0, and — color 0 being invisible to the forbidden bitsets — act
    exactly like the uncolored through-vertices of partial/bipartite D2
    coloring.
    """
    if not cfg.partial:
        assert marked is None, "marked= requires partial=True on the config"
        return order
    assert marked is not None, "partial=True needs a marked= (P, n_local) mask"
    order = np.asarray(order)
    marked = np.asarray(marked, dtype=bool)
    keep = np.take_along_axis(marked, np.maximum(order, 0), axis=1)
    return np.where((order >= 0) & keep, order, -1)


def color_graph_sim(pg: PartitionedGraph, order, cfg: ColorConfig,
                    key=None, *, marked=None):
    """Run distributed coloring *simulated* on one device (P vmap lanes).

    ``order`` — ``(P, n_local_max)`` int32 visit order of local slots, -1 =
    skip (``compute_order``); ``key`` — JAX key (default
    ``key(cfg.seed)``); ``marked`` — ``(P, n_local_max)`` bool host mask,
    only with ``cfg.partial``.  Returns ``(view, stats)``: ``view`` is the
    ``(P, n_slots)`` int32 device view (colors are 1-based; ghosts +
    sentinel slots after ``n_local_max``; ``colors_from_views`` flattens it
    to global ``(n,)`` colors) and ``stats`` are python ints — ``n_colors``
    (max id), ``n_colors_distinct`` (the quality metric), ``n_rounds``,
    ``n_exchanges``, ``wire_bytes`` (measured, per-shard max).
    ``color_graph_sharded`` is the bitwise-identical mesh variant.
    """
    cfg = resolve_cfg(pg, cfg)
    arrs = {k: jnp.asarray(v) for k, v in
            pg.arrays(sparse=cfg.scheme == SPARSE).items()}
    if key is None:
        key = jax.random.key(cfg.seed)
    order = _apply_partial(order, cfg, marked)
    view, stats = _sim_fn(pg.P, cfg, _plan_static(pg, cfg))(
        arrs, jnp.asarray(order), key)
    return view, stats_to_host(stats)


def color_graph_sharded(pg: PartitionedGraph, order, cfg: ColorConfig, mesh,
                        key=None, *, marked=None):
    """Run distributed coloring on a real mesh shard axis
    (``shard_axis_of(mesh)``, ``"workers"`` on the standard meshes) via
    shard_map; same contract and bitwise the same results as
    ``color_graph_sim``."""
    cfg = resolve_cfg(pg, cfg)
    arrs = {k: jnp.asarray(v) for k, v in
            pg.arrays(sparse=cfg.scheme == SPARSE).items()}
    if key is None:
        key = jax.random.key(cfg.seed)
    order = _apply_partial(order, cfg, marked)
    axis = shard_axis_of(mesh)
    fn = partial(color_spmd, cfg=cfg, P_size=pg.P,
                 plan_static=_plan_static(pg, cfg), axis=axis)
    view, stats = jax.jit(
        lambda a, o, k: run_sharded(fn, mesh, (a, o), (k,), axis=axis))(
            arrs, jnp.asarray(order), key)
    return view, stats_to_host(stats)
