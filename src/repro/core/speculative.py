"""Speculative greedy distributed coloring (Bozdağ et al. framework, §2.2).

Round structure (all inside one jitted SPMD function):

  while conflicts remain:
    compact uncolored vertices to the front of the visit order
    for each superstep chunk of `superstep` vertices:
        color the chunk (local view, possibly stale ghosts) — see below
        exchange boundary colors (every `exchange_every` supersteps; =1 is the
        paper's synchronous variant, >1 models asynchronous staleness)
    final boundary exchange
    detect conflicts on all local edges; the lower-priority endpoint is
    uncolored and queued for the next round (random total order tie-break)

Chunk coloring has two modes (``ColorConfig.parallel_chunk``):

  parallel (default) — the whole superstep tile colors at once against the
    stale view: one ELL gather of neighbour colors, then tile-parallel bitset
    selection through ``kernels.ops.select_colors`` (Pallas on TPU).  Vertices
    inside one chunk cannot see each other, so same-chunk neighbours may
    conflict — that is *legal* in the speculative framework, and the existing
    round loop repairs it (the highest-priority endpoint always survives, so
    every round makes progress).  This is the bulk-synchronous shape of
    Bogle & Slota / Rokos et al. and the fast path on wide SIMD hardware.
  sequential — the paper-faithful scalar loop: one vertex at a time inside the
    chunk, each seeing all earlier in-chunk colors (conflicts only ever
    involve boundary vertices).  Also used for Least-Used selection, whose
    running usage histogram is inherently sequential.

The same function serves initial coloring (any order, any selection strategy
incl. Random-X Fit) and the aRC second pass (order derived from a previous
coloring's classes).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.kernels import ops

from . import selection as sel
from .comm import AXIS, AxisComm, exchange_boundary, run_sharded, run_sim
from .graph import PartitionedGraph


def validate_color_bounds(max_colors: int, wire16: bool, backend: str):
    """Shared config guards for ColorConfig / RecolorConfig."""
    assert max_colors % 32 == 0, "max_colors must be 32-aligned"
    if wire16:
        # int16 wire payloads carry the color value itself; anything past
        # int16 range would silently alias colors after the exchange.
        assert max_colors <= 32767, (
            f"wire16 carries colors as int16; max_colors="
            f"{max_colors} exceeds 32767")
    assert backend in ops.BACKENDS, f"bad backend {backend!r}"


@dataclasses.dataclass(frozen=True)
class ColorConfig:
    """Static configuration of one distributed coloring run."""

    max_colors: int = 1024
    superstep: int = 512           # paper's superstep size (vertices per chunk)
    selection: str = sel.FIRST_FIT
    random_x: int = 10             # X for Random-X Fit
    stagger_estimate: int = 64     # initial color estimate for Staggered FF
    exchange_every: int = 1        # 1 = synchronous; k>1 = bounded staleness
    max_rounds: int = 64
    wire16: bool = False           # int16 boundary payloads (half ICI bytes)
    parallel_chunk: bool = True    # tile-parallel supersteps (False = paper's
                                   # sequential scalar loop, bitwise-preserved)
    tile: int = 128                # vertices colored simultaneously within a
                                   # superstep; bounds speculative conflicts
                                   # while `superstep` keeps the comm cadence
    backend: str = "auto"          # kernels.ops backend: auto | xla | pallas
    seed: int = 0

    def __post_init__(self):
        validate_color_bounds(self.max_colors, self.wire16, self.backend)
        assert self.tile > 0

    @property
    def n_words(self) -> int:
        return self.max_colors // 32

    @property
    def use_parallel_chunk(self) -> bool:
        """Least-Used chases a running histogram -> stays sequential."""
        return self.parallel_chunk and self.selection != sel.LEAST_USED

    def stagger_offset(self, p_idx):
        """Staggered First Fit start color of processor ``p_idx``."""
        return (p_idx * self.stagger_estimate) % self.max_colors


def _forbidden_words(view, indptr, indices, v, n_words):
    """Bitset of neighbour colors of local vertex `v` under current view."""
    words = jnp.zeros((n_words,), dtype=jnp.uint32).at[0].set(jnp.uint32(1))

    def body(e, words):
        return sel.set_bit(words, view[indices[e]])

    return jax.lax.fori_loop(indptr[v], indptr[v + 1], body, words)


def _pick_color(words, usage, v_rand, p_idx, cfg: ColorConfig):
    if cfg.selection == sel.FIRST_FIT:
        return sel.first_fit(words)
    if cfg.selection == sel.STAGGERED:
        return sel.staggered(words, cfg.stagger_offset(p_idx))
    if cfg.selection == sel.LEAST_USED:
        return sel.least_used(words, usage)
    if cfg.selection == sel.RANDOM_X:
        return sel.random_x(words, cfg.random_x, v_rand)
    raise ValueError(f"unknown selection {cfg.selection!r}")


def _greedy_chunk(view, usage, order, rand_u32, start, count, arrs, p_idx,
                  cfg: ColorConfig):
    """Sequentially color `order[start:start+count]` (the superstep body)."""
    indptr, indices = arrs["indptr"], arrs["indices"]

    def body(i, carry):
        view, usage = carry
        v = order[i]
        v_safe = jnp.maximum(v, 0)
        needs = (v >= 0) & (view[v_safe] == 0)

        def color_one(args):
            view, usage = args
            words = _forbidden_words(view, indptr, indices, v_safe, cfg.n_words)
            c = _pick_color(words, usage, rand_u32[v_safe], p_idx, cfg)
            c = jnp.minimum(c, cfg.max_colors - 1).astype(jnp.int32)
            return view.at[v_safe].set(c), usage.at[c].add(1)

        return jax.lax.cond(needs, color_one, lambda a: a, (view, usage))

    return jax.lax.fori_loop(start, start + count, body, (view, usage))


def _parallel_chunk(view, usage, order_pad, rand_u32, start, arrs, p_idx,
                    cfg: ColorConfig):
    """Color one superstep as tile-parallel sub-tiles against the stale view.

    Each sub-tile of ``cfg.tile`` vertices colors at once: one ELL-row gather
    + one bitset selection through ``kernels.ops.select_colors``.  The view
    updates between sub-tiles (so speculative conflicts stay bounded by the
    tile width), while boundary exchanges keep the ``superstep`` cadence —
    the tile is a hardware knob, the superstep the paper's comm knob.
    Conflicts within a tile are repaired by the round loop.  ``order_pad`` is
    the visit order padded by ``superstep`` entries of -1 so slices never
    clamp into unvisited territory.
    """
    n_slots = view.shape[0]
    tile = min(cfg.tile, cfg.superstep)
    n_tiles = -(-cfg.superstep // tile)
    offset = cfg.stagger_offset(p_idx)

    def tile_body(ti, carry):
        view, usage = carry
        chunk = jax.lax.dynamic_slice(order_pad, (start + ti * tile,), (tile,))
        v_safe = jnp.maximum(chunk, 0)
        active = (chunk >= 0) & (view[v_safe] == 0)
        nbr_colors = view[arrs["nbr"][v_safe]]       # (tile, maxd)
        colors = ops.select_colors(
            nbr_colors, active, rand_u32[v_safe], max_colors=cfg.max_colors,
            selection=cfg.selection, x=cfg.random_x, offset=offset,
            backend=cfg.backend)
        colors = jnp.minimum(colors, cfg.max_colors - 1).astype(jnp.int32)
        idx = jnp.where(active, v_safe, n_slots - 1)   # park writes on the
        val = jnp.where(active, colors, 0)             # sentinel (stays 0)
        view = view.at[idx].set(val.astype(view.dtype))
        usage = usage.at[jnp.where(active, colors, 0)].add(
            active.astype(jnp.int32))
        return view, usage

    return jax.lax.fori_loop(0, n_tiles, tile_body, (view, usage))


def _detect_conflicts(view, arrs, n_local_max, backend="auto"):
    """Uncolor the lower-priority endpoint of every same-color edge.

    Gather-only on the ELL layout (one row per local vertex) routed through
    the shared conflict kernel — no scatter over the edge list.
    """
    nbr, prio = arrs["nbr"], arrs["prio"]
    my_color = view[:n_local_max]
    my_prio = prio[:n_local_max]
    conf = ops.detect_conflicts(my_color, my_prio, view[nbr], prio[nbr],
                                jnp.ones((n_local_max,), bool),
                                backend=backend)
    new_local = jnp.where(conf, 0, my_color)
    view = jax.lax.dynamic_update_slice(view, new_local.astype(view.dtype), (0,))
    return view, jnp.sum(conf, dtype=jnp.int32)


def _compact_order(order, view):
    """Stable-move still-uncolored vertices to the front of the visit order."""
    v_safe = jnp.maximum(order, 0)
    needs = (order >= 0) & (view[v_safe] == 0)
    perm = jnp.argsort(~needs, stable=True)
    return order[perm], jnp.sum(needs, dtype=jnp.int32)


def color_spmd(arrs, order, key, cfg: ColorConfig):
    """Per-shard SPMD speculative coloring. Returns (view, stats dict)."""
    comm = AxisComm()
    n_local_max = arrs["indptr"].shape[0] - 1
    n_slots = arrs["prio"].shape[0]
    p_idx = comm.index()

    exchange = partial(exchange_boundary, boundary=arrs["boundary"],
                       ghost_owner=arrs["ghost_owner"],
                       ghost_slot=arrs["ghost_slot"],
                       n_local_max=n_local_max, comm=comm,
                       wire_dtype=jnp.int16 if cfg.wire16 else None)

    view0 = jnp.zeros((n_slots,), jnp.int32)
    usage0 = jnp.zeros((cfg.max_colors,), jnp.int32)

    def round_body(state):
        view, usage, rnd, _, n_ex = state
        order_r, n_need = _compact_order(order, view)
        n_need_max = comm.pmax(n_need)
        n_steps = (n_need_max + cfg.superstep - 1) // cfg.superstep
        rkey = jax.random.fold_in(jax.random.fold_in(key, rnd), p_idx)
        rand_u32 = jax.random.bits(rkey, (n_slots,), jnp.uint32)
        order_pad = jnp.concatenate(
            [order_r, jnp.full((cfg.superstep,), -1, order_r.dtype)])

        def superstep(si, carry):
            view, usage, n_ex = carry
            if cfg.use_parallel_chunk:
                view, usage = _parallel_chunk(view, usage, order_pad,
                                              rand_u32, si * cfg.superstep,
                                              arrs, p_idx, cfg)
            else:
                view, usage = _greedy_chunk(view, usage, order_r, rand_u32,
                                            si * cfg.superstep, cfg.superstep,
                                            arrs, p_idx, cfg)
            do_ex = ((si + 1) % cfg.exchange_every == 0) | (si == n_steps - 1)
            view = jax.lax.cond(do_ex, exchange, lambda v: v, view)
            return view, usage, n_ex + do_ex.astype(jnp.int32)

        view, usage, n_ex = jax.lax.fori_loop(
            0, n_steps, superstep, (view, usage, n_ex))
        view, n_conf = _detect_conflicts(view, arrs, n_local_max,
                                         backend=cfg.backend)
        view = exchange(view)
        n_conf = comm.psum(n_conf)
        return view, usage, rnd + 1, n_conf, n_ex + 1

    def cond(state):
        _, _, rnd, n_conf, _ = state
        return (n_conf > 0) & (rnd < cfg.max_rounds)

    state0 = (view0, usage0, jnp.int32(0), jnp.int32(1), jnp.int32(0))
    # round 0 must run: seed n_conf=1
    view, usage, n_rounds, _, n_ex = jax.lax.while_loop(cond, round_body, state0)

    local_max = jnp.max(view[:n_local_max])
    stats = dict(
        n_colors=comm.pmax(local_max),
        n_rounds=n_rounds,
        n_exchanges=n_ex,
    )
    return view, stats


@lru_cache(maxsize=64)
def _sim_fn(P, cfg):
    fn = partial(color_spmd, cfg=cfg)
    return jax.jit(lambda arrs, order, key: run_sim(fn, P, (arrs, order), (key,)))


def color_graph_sim(pg: PartitionedGraph, order, cfg: ColorConfig,
                    key=None):
    """Run distributed coloring *simulated* on one device (P vmap lanes)."""
    arrs = {k: jnp.asarray(v) for k, v in pg.arrays().items()}
    if key is None:
        key = jax.random.key(cfg.seed)
    view, stats = _sim_fn(pg.P, cfg)(arrs, jnp.asarray(order), key)
    return view, {k: int(v[0]) if v.ndim else int(v) for k, v in stats.items()}


def color_graph_sharded(pg: PartitionedGraph, order, cfg: ColorConfig, mesh,
                        key=None):
    """Run distributed coloring on a real mesh axis ``workers``."""
    arrs = {k: jnp.asarray(v) for k, v in pg.arrays().items()}
    if key is None:
        key = jax.random.key(cfg.seed)
    fn = partial(color_spmd, cfg=cfg)
    view, stats = jax.jit(
        lambda a, o, k: run_sharded(fn, mesh, (a, o), (k,)))(
            arrs, jnp.asarray(order), key)
    return view, {k: int(jnp.max(v)) for k, v in stats.items()}
