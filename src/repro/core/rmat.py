"""Synthetic graph generators.

The paper (§4.1) evaluates on six real-world graphs (UF sparse collection /
Parasol) and three RMAT graphs: RMAT-ER (0.25,0.25,0.25,0.25),
RMAT-Good (0.45,0.15,0.15,0.25) and RMAT-Bad (0.55,0.15,0.15,0.15).
The UF graphs are not available offline, so the real-world suite is stood in
for by structured finite-element-style grid graphs (2D 9-point / 3D 27-point
stencils), which share the properties the paper relies on (low, bounded degree,
good partitions), plus the three RMAT classes at CPU-feasible scale.

All generators return a symmetric, dedup'ed, self-loop-free CSR graph.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph, _unique_pairs, id_policy


def _dedup_edges(u: np.ndarray, v: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Sort directed edges by (u, v) and drop duplicates.

    Lexsort-based on purpose: the former packed key ``u * n + v`` overflows
    int64 once ``n`` reaches 2**32 (RMAT scale >= 32) — the wrapped keys still
    dedup (the packing is injective mod 2**64) but decode back to *negative*
    endpoints, corrupting the CSR. Sorting the coordinate pairs directly has
    no packing step to overflow.
    """
    return _unique_pairs(u, v)


def _edges_to_graph(n: int, src: np.ndarray, dst: np.ndarray) -> Graph:
    """Symmetrize + dedup an edge list into CSR."""
    # CSR id width comes from the id policy: int32 below the 2**31 vertex
    # bound, int64 past it (only the int64 ceiling still fails loudly).
    pol = id_policy(n, 1, 1)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    u, v = _dedup_edges(np.concatenate([src, dst]), np.concatenate([dst, src]))
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, u.astype(np.int64) + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(n=n, indptr=indptr.astype(np.int64),
                 indices=v.astype(pol.id_dtype))


def rmat(
    scale: int,
    edge_factor: int = 8,
    probs: tuple[float, float, float, float] = (0.25, 0.25, 0.25, 0.25),
    seed: int = 0,
) -> Graph:
    """R-MAT generator (Chakrabarti et al.), recursive quadrant sampling.

    ``scale``: log2 of the number of vertices. ``edge_factor``: directed edges
    generated per vertex before symmetrization/dedup.
    """
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    a, b, c, d = probs
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    # Vectorized: one random draw per (edge, level).
    for _ in range(scale):
        r = rng.random(m)
        right = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        down = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src = src * 2 + right.astype(np.int64)
        dst = dst * 2 + down.astype(np.int64)
    # ids stay int64 through the dedup; _edges_to_graph picks the CSR id
    # width from id_policy (int32 below scale 31, int64 past it)
    return _edges_to_graph(n, src, dst)


def rmat_er(scale: int, edge_factor: int = 8, seed: int = 0) -> Graph:
    return rmat(scale, edge_factor, (0.25, 0.25, 0.25, 0.25), seed)


def rmat_good(scale: int, edge_factor: int = 8, seed: int = 0) -> Graph:
    return rmat(scale, edge_factor, (0.45, 0.15, 0.15, 0.25), seed)


def rmat_bad(scale: int, edge_factor: int = 8, seed: int = 0) -> Graph:
    return rmat(scale, edge_factor, (0.55, 0.15, 0.15, 0.15), seed)


def grid2d(rows: int, cols: int, stencil: int = 9) -> Graph:
    """2D grid with a 5- or 9-point stencil — FE-mesh stand-in (auto/hood-like)."""
    assert stencil in (5, 9)
    n = rows * cols
    ii, jj = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    # promote at the packing site: id * size + id wraps at 2**31 if the
    # operands ride on int32 (NEP 50 keeps the array dtype against python
    # ints) — cf. the former u*n+v dedup-key overflow, PR 3
    vid = (ii.astype(np.int64) * cols + jj).ravel()
    offsets = [(-1, 0), (1, 0), (0, -1), (0, 1)]
    if stencil == 9:
        offsets += [(-1, -1), (-1, 1), (1, -1), (1, 1)]
    srcs, dsts = [], []
    for di, dj in offsets:
        ni, nj = ii + di, jj + dj
        ok = (ni >= 0) & (ni < rows) & (nj >= 0) & (nj < cols)
        srcs.append(vid[ok.ravel()])
        dsts.append((ni.astype(np.int64) * cols + nj).ravel()[ok.ravel()])
    return _edges_to_graph(n, np.concatenate(srcs).astype(np.int32),
                           np.concatenate(dsts).astype(np.int32))


def grid3d(nx: int, ny: int, nz: int) -> Graph:
    """3D grid, 27-point stencil — structural-engineering-mesh stand-in."""
    n = nx * ny * nz
    ii, jj, kk = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij")
    vid = (ii.astype(np.int64) * ny * nz + jj * nz + kk).ravel()
    srcs, dsts = [], []
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                if di == dj == dk == 0:
                    continue
                ni, nj, nk = ii + di, jj + dj, kk + dk
                ok = ((ni >= 0) & (ni < nx) & (nj >= 0) & (nj < ny)
                      & (nk >= 0) & (nk < nz))
                srcs.append(vid[ok.ravel()])
                dsts.append((ni.astype(np.int64) * ny * nz + nj * nz
                             + nk).ravel()[ok.ravel()])
    return _edges_to_graph(n, np.concatenate(srcs).astype(np.int32),
                           np.concatenate(dsts).astype(np.int32))


def random_regular_ish(n: int, deg: int, seed: int = 0) -> Graph:
    """Erdős–Rényi-flavoured graph with ~deg average degree."""
    rng = np.random.default_rng(seed)
    m = n * deg // 2
    src = rng.integers(0, n, m, dtype=np.int64).astype(np.int32)
    dst = rng.integers(0, n, m, dtype=np.int64).astype(np.int32)
    return _edges_to_graph(n, src, dst)


def geometric(n: int, avg_deg: float = 24.0, seed: int = 0,
              dims: int = 2) -> Graph:
    """Random geometric (unit-disk) graph — the closest synthetic analogue of
    the paper's FE meshes: local cliques, 30–50 greedy colors, orderings and
    class permutations matter. Built with cell-binned neighbour join."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, dims))
    # radius for expected degree: deg = n * V_d * r^d
    vd = np.pi if dims == 2 else 4.0 / 3.0 * np.pi
    r = (avg_deg / (n * vd)) ** (1.0 / dims)
    cell = r
    grid_n = max(int(1.0 / cell), 1)
    cid = np.minimum((pts / cell).astype(np.int64), grid_n - 1)
    # promote at the packing site (PR 3): the cell key must not wrap int32
    key = cid[:, 0].astype(np.int64) * grid_n + cid[:, 1] if dims == 2 else (
        (cid[:, 0].astype(np.int64) * grid_n + cid[:, 1]) * grid_n
        + cid[:, 2])
    order = np.argsort(key)
    srcs, dsts = [], []
    offsets = ([(i, j) for i in (-1, 0, 1) for j in (-1, 0, 1)] if dims == 2
               else [(i, j, k) for i in (-1, 0, 1) for j in (-1, 0, 1)
                     for k in (-1, 0, 1)])
    # bucket index: key -> member ids
    skey = key[order]
    starts = np.searchsorted(skey, np.arange(grid_n ** dims))
    ends = np.searchsorted(skey, np.arange(grid_n ** dims), side="right")

    def members(c):
        k = int(c[0]) * grid_n + int(c[1]) if dims == 2 else (
            (int(c[0]) * grid_n + int(c[1])) * grid_n + int(c[2]))
        return order[starts[k]:ends[k]]

    for cx in range(grid_n):
        for cy in range(grid_n):
            cells = [(cx, cy)] if dims == 2 else [
                (cx, cy, cz) for cz in range(grid_n)]
            for base in cells:
                a = members(base)
                if len(a) == 0:
                    continue
                neigh = []
                for off in offsets:
                    c2 = tuple(b + o for b, o in zip(base, off))
                    if all(0 <= v < grid_n for v in c2):
                        neigh.append(members(c2))
                b = np.concatenate(neigh)
                d2 = ((pts[a][:, None, :] - pts[b][None, :, :]) ** 2).sum(-1)
                ii, jj = np.nonzero(d2 <= r * r)
                srcs.append(a[ii])
                dsts.append(b[jj])
    return _edges_to_graph(n, np.concatenate(srcs).astype(np.int32),
                           np.concatenate(dsts).astype(np.int32))


# The paper's evaluation suite, scaled to this container. Keys mirror Table 1/2.
SUITE_REAL = {
    # name -> constructor (FE-style stand-ins for the UF/Parasol graphs)
    "grid2d_9pt": lambda: grid2d(256, 256, 9),
    "grid3d_27pt": lambda: grid3d(32, 32, 32),
    "geo2d": lambda: geometric(1 << 15, 28, seed=3),
    "geo3d": lambda: geometric(1 << 14, 36, seed=4, dims=3),
}
SUITE_RMAT = {
    "rmat_er": lambda: rmat_er(14, 8, seed=1),
    "rmat_good": lambda: rmat_good(14, 8, seed=1),
    "rmat_bad": lambda: rmat_bad(14, 8, seed=1),
}
