"""Vertex-visit orderings (paper §2.1, §2.2.1).

Each processor computes an ordering of *its own* vertices from the knowledge
it has (paper: "we let each processor compute an ordering of the graph based
on the knowledge it has"), so the distributed ordering differs from the
sequential one — which is exactly the effect the paper studies.

Orders are host-side preprocessing (numpy) and are returned as
``(P, n_local_max)`` arrays of local slot ids, padded with -1 (skipped).
"""
from __future__ import annotations

import numpy as np

from .graph import PartitionedGraph

NATURAL = "natural"
LARGEST_FIRST = "lf"
SMALLEST_LAST = "sl"
INTERNAL_FIRST = "internal_first"
BOUNDARY_FIRST = "boundary_first"

ALL_ORDERINGS = (NATURAL, LARGEST_FIRST, SMALLEST_LAST, INTERNAL_FIRST,
                 BOUNDARY_FIRST)


def _sl_local(pg: PartitionedGraph, p: int) -> np.ndarray:
    """Smallest-last over processor p's owned vertices (bucket queue, O(E))."""
    nl = int(pg.n_local[p])
    indptr = pg.indptr[p]
    indices = pg.indices[p]
    deg = pg.degree[p, :nl].astype(np.int64).copy()
    maxd = int(deg.max(initial=0))
    # bucket queue
    order = np.empty(nl, dtype=np.int32)
    removed = np.zeros(nl, dtype=bool)
    buckets: list[list[int]] = [[] for _ in range(maxd + 1)]
    for v in range(nl):
        buckets[deg[v]].append(v)
    cur = 0
    for k in range(nl - 1, -1, -1):
        # find the minimum-degree live vertex (lazy deletion of stale entries)
        while True:
            while cur <= maxd and not buckets[cur]:
                cur += 1
            v = buckets[cur].pop()
            if not removed[v] and deg[v] == cur:
                break
        removed[v] = True
        order[k] = v
        for e in range(indptr[v], indptr[v + 1]):
            u = indices[e]
            if u < nl and not removed[u]:
                deg[u] -= 1
                buckets[deg[u]].append(u)
                if deg[u] < cur:
                    cur = deg[u]
    return order


def compute_order(pg: PartitionedGraph, kind: str, *, seed: int = 0) -> np.ndarray:
    """(P, n_local_max) int32 visit order of local slots, padded with -1."""
    P, nmax = pg.P, pg.n_local_max
    out = np.full((P, nmax), -1, dtype=np.int32)
    for p in range(P):
        nl = int(pg.n_local[p])
        if nl == 0:
            continue
        if kind == NATURAL:
            o = np.arange(nl, dtype=np.int32)
        elif kind == LARGEST_FIRST:
            # stable sort, non-increasing degree (Welsh–Powell)
            o = np.argsort(-pg.degree[p, :nl], kind="stable").astype(np.int32)
        elif kind == SMALLEST_LAST:
            o = _sl_local(pg, p)
        elif kind == INTERNAL_FIRST:
            internal = np.nonzero(pg.is_internal[p, :nl])[0]
            boundary = np.nonzero(~pg.is_internal[p, :nl])[0]
            o = np.concatenate([internal, boundary]).astype(np.int32)
        elif kind == BOUNDARY_FIRST:
            internal = np.nonzero(pg.is_internal[p, :nl])[0]
            boundary = np.nonzero(~pg.is_internal[p, :nl])[0]
            o = np.concatenate([boundary, internal]).astype(np.int32)
        else:
            raise ValueError(f"unknown ordering {kind!r}")
        out[p, :nl] = o
    return out
