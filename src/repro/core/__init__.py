"""Distributed graph coloring with iterative recoloring — core library.

Public API:
  Graph, PartitionedGraph, partition_graph      — graph substrate
  pad_partition, bucket_graphs, GraphBucket      — batched shape buckets
  compute_order                                  — vertex-visit orderings
  ColorConfig, color_graph_sim/_sharded          — speculative coloring
  RecolorConfig, recolor_sim/_sharded, arc_sim   — iterative recoloring
  recolor_iterations, schedule_for_iteration     — ND-RAND%x schedules
  PipelineConfig, pipeline_sim/_sharded          — fused device-resident
                                                   color→recolor pipeline
  color_many, color_many_sharded                 — batched multi-graph
                                                   pipeline (DESIGN.md §8)
  PlanSignature, plan_signature                  — compiled-program identity
  program_cache_stats, program_cache_clear       — process-wide program-cache
                                                   counters (hits/misses/traces)
  resolve_scheme                                 — trace-time sparse-vs-
                                                   allgather decision ("auto")
  IdPolicy, id_policy, check_int32_limits        — id-width policy: int32
                                                   under 2**31, int64 past it
  shard_axis_of, batch_axis_size, mesh_axes      — mesh axis-name contract
                                                   (DESIGN.md §10)
  message_stats                                  — piggybacking accounting
  presets.speed / presets.quality                — the paper's parameter sets
  select_colors                                  — shared bitset color-selection
                                                   entry (Pallas/XLA backends)
"""
from repro.kernels.ops import select_colors, select_colors_d2

from . import ordering, presets, rmat, selection
from .comm import (AUTO, AXIS, BATCH_AXIS, SCHEME_CHOICES, SCHEMES, AxisComm,
                   CommConfig, allgather_bytes_per_exchange, batch_axis_of,
                   batch_axis_size, mesh_axes, resolve_scheme, shard_axis_of,
                   stats_to_host)
from .graph import (CommPlan, Graph, GraphBucket, IdPolicy, PartitionedGraph,
                    bucket_graphs, build_comm_plan, check_int32_limits,
                    id_policy, pad_partition, partition_graph, plan_fits,
                    remap_plan_arrays)
from .ordering import compute_order
from .piggyback import MessageStats, message_stats
from .pipeline import (PipelineConfig, PlanSignature, bucket_signature,
                       color_many, color_many_sharded, color_then_recolor,
                       engine_init_program, engine_put_program,
                       engine_step_program,
                       pipeline_carry_spmd, pipeline_sharded, pipeline_sim,
                       pipeline_step_spmd, plan_signature,
                       program_cache_clear, program_cache_contains,
                       program_cache_stats, recolor_carry_init,
                       recolor_loop_sim, resolve_pipeline_cfg)
from .recolor import (ND, NI, RAND, RV, RecolorConfig, arc_sim,
                      recolor_iterations, recolor_sharded, recolor_sim,
                      schedule_for_iteration)
from .speculative import (ColorConfig, color_graph_sharded, color_graph_sim,
                          color_spmd)
from .validate import assert_valid, check_coloring, colors_from_views

__all__ = [
    "AUTO", "AXIS", "AxisComm", "BATCH_AXIS", "ColorConfig", "CommConfig",
    "CommPlan", "Graph", "GraphBucket", "IdPolicy", "MessageStats", "ND",
    "NI", "PartitionedGraph",
    "PipelineConfig", "PlanSignature", "RAND", "RV", "RecolorConfig",
    "SCHEME_CHOICES", "SCHEMES", "allgather_bytes_per_exchange", "arc_sim",
    "assert_valid", "batch_axis_of", "batch_axis_size", "bucket_graphs",
    "build_comm_plan", "check_coloring", "check_int32_limits",
    "bucket_signature", "color_graph_sharded", "color_graph_sim",
    "color_many", "color_many_sharded", "color_spmd", "color_then_recolor",
    "colors_from_views", "compute_order", "engine_init_program",
    "engine_put_program", "engine_step_program", "id_policy", "mesh_axes",
    "message_stats", "ordering",
    "pad_partition", "partition_graph", "pipeline_carry_spmd",
    "pipeline_sharded", "pipeline_sim", "pipeline_step_spmd",
    "plan_fits", "plan_signature", "presets", "program_cache_clear",
    "program_cache_contains", "program_cache_stats", "recolor_carry_init",
    "recolor_iterations", "recolor_loop_sim", "remap_plan_arrays",
    "recolor_sharded", "recolor_sim", "resolve_pipeline_cfg",
    "resolve_scheme", "rmat", "schedule_for_iteration", "select_colors",
    "select_colors_d2", "selection", "shard_axis_of", "stats_to_host",
]
