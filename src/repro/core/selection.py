"""Color-selection strategies (paper §2.1, §3.2).

A vertex's permissible set is represented as a forbidden *bitset*: ``words``
of dtype uint32, ``max_colors // 32`` of them; bit ``c`` set means color ``c``
is taken by a neighbour. Bit 0 is always set (colors are 1-based), so
find-first-zero directly yields the First Fit color.

Strategies:
  FIRST_FIT      — smallest permissible color (Alg. 1).
  STAGGERED      — First Fit starting from a per-processor offset, wrapping
                   (Bozdağ et al.'s Staggered First Fit).
  LEAST_USED     — locally least-used permissible color.
  RANDOM_X       — uniform among the X smallest permissible colors
                   (Gebremedhin et al.; the paper's §3.2 initial coloring).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FIRST_FIT = "first_fit"
STAGGERED = "staggered"
LEAST_USED = "least_used"
RANDOM_X = "random_x"

UINT1 = jnp.uint32(1)


def set_bit(words: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Set bit `c` (int32 scalar in [0, 32*W)) in the word array."""
    w = c >> 5
    bit = UINT1 << (c & 31).astype(jnp.uint32)
    return words.at[w].set(words[w] | bit)


def find_first_zero(words: jnp.ndarray) -> jnp.ndarray:
    """Index of the lowest zero bit below the sentinel; `32*W - 1` if none.

    The top bit (color ``32*W - 1``) is *reserved as a saturation sentinel*:
    it is never reported as free, so a return value of ``32*W - 1`` always
    means "no permissible color".  Without the reservation the same value was
    ambiguous ("full" vs "the last bit is genuinely free"), which made
    ``staggered`` wrap below its offset when color ``32*W - 1`` was legal.
    """
    W = words.shape[0]
    free = (~words).at[W - 1].set(~words[W - 1] & jnp.uint32(0x7FFFFFFF))
    has = free != 0
    widx = jnp.min(jnp.where(has, jnp.arange(W), W))
    widx_c = jnp.minimum(widx, W - 1)
    word = free[widx_c]
    lsb = word & (~word + UINT1)
    bit = jax.lax.population_count(lsb - UINT1).astype(jnp.int32)
    out = widx_c.astype(jnp.int32) * 32 + bit
    return jnp.where(widx >= W, 32 * W - 1, out)


def _mask_below(words: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Return a copy of `words` with all bits < c additionally set."""
    W = words.shape[0]
    widx = c >> 5
    rem = (c & 31).astype(jnp.uint32)
    full = jnp.arange(W) < widx
    partial_mask = jnp.where(jnp.arange(W) == widx,
                             (UINT1 << rem) - UINT1, jnp.uint32(0))
    return words | jnp.where(full, jnp.uint32(0xFFFFFFFF), 0).astype(
        jnp.uint32) | partial_mask


def first_fit(words):
    return find_first_zero(words)


def staggered(words, offset):
    """First fit from `offset`, wrap to plain first fit if exhausted."""
    c = find_first_zero(_mask_below(words, offset))
    full = c >= words.shape[0] * 32 - 1
    return jnp.where(full, find_first_zero(words), c)


def least_used(words, usage):
    """Least-used permissible *already-open* color; first fit if none is open.

    Ties break to the smaller color. Restricting to already-used colors keeps
    the strategy from opening a new color when an existing one is permissible
    (the "(locally) least used color so far" of §2.1).  The top color
    ``mc - 1`` is the saturation sentinel (see ``find_first_zero``) and is
    never handed out, even if a saturated clamp put it in ``usage``.
    """
    mc = usage.shape[0]
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & UINT1
    forbidden = bits.reshape(-1)[:mc].astype(bool)
    big = jnp.iinfo(jnp.int32).max
    key = jnp.where(forbidden | (usage == 0)
                    | (jnp.arange(mc) == mc - 1), big, usage)
    best = jnp.lexsort((jnp.arange(mc, dtype=jnp.int32), key))[0]
    none_open = key[best] == big
    return jnp.where(none_open, find_first_zero(words),
                     best.astype(jnp.int32))


def random_x(words, x: int, rand_u32):
    """Uniform choice among the `x` smallest permissible colors.

    `x` is static; `rand_u32` is this vertex's per-round random draw.
    """
    def body(k, carry):
        words, cands = carry
        c = find_first_zero(words)
        cands = cands.at[k].set(c)
        return set_bit(words, c), cands

    mc = words.shape[0] * 32
    cands = jnp.full((x,), mc - 1, dtype=jnp.int32)
    _, cands = jax.lax.fori_loop(0, x, body, (words, cands))
    n_free = jnp.sum(cands < mc - 1).astype(jnp.uint32)
    n_free = jnp.maximum(n_free, jnp.uint32(1))
    idx = (rand_u32 % n_free).astype(jnp.int32)
    return cands[idx]
