"""Fused device-resident coloring → iterative-recoloring pipeline.

The paper's central result is a *loop*: a cheap speculative initial coloring
followed by multiple recoloring iterations dominates the time-quality Pareto
front.  The host-looped form (``recolor_iterations`` dispatching one
``recolor_sim`` per iteration) pays a host-device round-trip per iteration —
the color view and every stat sync through the host, and each permutation
kind traces its own program.  ``color_then_recolor`` keeps the whole
experiment resident on device, the "communicate only what changed"
discipline of the distributed-GPU coloring literature (Bogle & Slota 2021;
Rokos et al. 2015) applied to the iteration loop itself:

- the initial speculative coloring (any selection/ordering, distance 1|2,
  ``partial``/``marked``) and K recoloring iterations run inside **one
  jitted program** — the comm plan, ELL arrays and exchange closures are
  bound once;
- the per-iteration permutation schedule (ND-RAND%x / ND-RAND%2^i, see
  ``schedule_for_iteration``) is resolved as **traced branches**
  (``permutation_rank_traced``): the kind id array is static per config, so
  no re-tracing per kind and the loop is a single ``lax.while_loop``;
- the RNG key is **folded per iteration** (``fold_in(key, it)``) — bitwise
  the same stream as the host loop, and two iterations never share a RAND
  permutation;
- **adaptive stopping**: the loop quits early once the global *distinct*
  color count has failed to improve for ``patience`` consecutive iterations
  (the paper's time-quality knob; ``patience=0`` always runs all K);
- per-iteration stats land in a device-resident ``(K, len(HISTORY_STATS))``
  int32 history (colors, distinct colors, exchanges, supersteps, wire bytes,
  out-of-range count, permutation id, ran flag), unpacked **once** at the
  end — the only host sync of the whole run.

``recolor_iterations`` is a thin wrapper over the recolor-only loop
(``recolor_loop_sim``); the host loop survives behind ``fused=False`` as the
bitwise reference (tests/test_pipeline.py pins fused == host at P ∈
{2, 4, 16}, both exchange schemes, distance 1 and 2).

**Batched multi-graph pipeline** (``color_many`` / ``color_many_sharded``,
DESIGN.md §8): production coloring traffic arrives as *many*
small-to-medium graphs (per-batch conflict graphs, per-tile sparsity
patterns), not one giant one.  ``bucket_graphs`` pads the partitions into
shape buckets; within a bucket the fused program is lifted over a leading
graph axis with ``vmap`` — per-graph RNG keys, per-graph ``(K, n_stats)``
histories, and a per-graph adaptive stop: ``vmap`` of ``lax.while_loop``
runs while *any* graph's predicate holds and select-masks the body on
finished lanes, so each lane's result is bitwise the solo run's
(tests/test_serve.py pins this per graph, across bucket boundaries, both
exchange schemes, distance 1 and 2).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ordering
from .comm import (ALLGATHER, AUTO, AXIS, SPARSE, AxisComm,
                   allgather_bytes_per_exchange, batch_axis_of,
                   batch_axis_size, mesh_axes, run_sharded, run_sharded_many,
                   run_sim, shard_axis_of, stats_to_host)
from .graph import PartitionedGraph, _ceil_pow2, bucket_graphs
from .ordering import compute_order
from .recolor import (ALL_PERMS, ND, PERM_IDS, RecolorConfig, class_sizes,
                      permutation_rank, permutation_rank_traced,
                      recolor_pass_spmd, schedule_for_iteration)
from .speculative import ColorConfig, _apply_partial, color_spmd, resolve_cfg

# Column layout of the device-resident per-iteration history.  ``ran`` marks
# rows the adaptive stop never reached (they stay zero).
HISTORY_STATS = ("n_colors", "n_colors_distinct", "n_colors_before",
                 "n_exchanges", "n_steps", "wire_bytes", "n_out_of_range",
                 "perm_id", "ran")


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Static configuration of the fused color→recolor pipeline.

    ``n_iters`` (K) caps the recoloring iterations; ``patience`` (in
    iterations, 0 = off) is the adaptive stop on the global distinct-color
    count; the color/recolor stages keep their own configs (``color=None``
    = recolor-only).  Drivers: ``pipeline_sim`` / ``pipeline_sharded`` for
    one graph, ``color_many`` / ``color_many_sharded`` for a bucketed
    batch — all four bitwise-identical per graph for the same keys.
    """

    color: ColorConfig | None = None  # None = recolor-only (seed view given)
    recolor: RecolorConfig = RecolorConfig()
    n_iters: int = 8               # K — max recoloring iterations
    base_perm: str = ND            # schedule base (paper's best: ND)
    rand_every: int = 0            # ND-RAND%x: RAND every x-th iteration
    rand_pow2: bool = False        # ND-RAND%2^i: RAND at power-of-two its
    patience: int = 0              # adaptive stop: quit after this many
                                   # non-improving iterations (0 = run all K)
    seed: int = 0                  # recoloring key seed (folded per it)

    def __post_init__(self):
        assert self.n_iters >= 0
        assert self.patience >= 0
        assert self.base_perm in ALL_PERMS, f"bad perm {self.base_perm!r}"
        if self.color is not None:
            assert self.color.distance == self.recolor.distance, (
                "one device layout serves both stages: color and recolor "
                "must agree on distance")

    @property
    def kind_ids(self) -> tuple:
        """Static per-iteration permutation ids (the ND-RAND%x schedule)."""
        return tuple(
            PERM_IDS[schedule_for_iteration(it, self.base_perm,
                                            self.rand_every, self.rand_pow2)]
            for it in range(1, self.n_iters + 1))

    @property
    def has_auto(self) -> bool:
        """True while any stage's scheme is still the unresolved "auto"."""
        return (self.recolor.scheme == AUTO
                or (self.color is not None and self.color.scheme == AUTO))

    @property
    def needs_sparse_plan(self) -> bool:
        assert not self.has_auto, (
            "scheme='auto' must be resolved against a partition first "
            "(resolve_pipeline_cfg)")
        return (self.recolor.scheme == SPARSE
                or (self.color is not None and self.color.scheme == SPARSE))


def _recolor_loop_fns(arrs, key, cfg: PipelineConfig,
                      P_size: int | None = None, plan_static=None,
                      axis: str = AXIS, lane_axes: tuple = ()):
    """The fused recolor loop's traced pieces: ``(body, cond, lane_on)``.

    ``body``/``cond`` close over ``arrs``/``key`` and operate on the carry
    built by ``recolor_carry_init``.  ``lane_on(state)`` is this lane's own
    adaptive-stop predicate (``cond`` is its mesh-uniform reduction).
    Factored out so the uninterrupted ``recolor_loop_spmd`` and the
    chunked ``pipeline_step_spmd`` run the *same* body — the body freezes
    a finished lane's carry via select-mask, so applying it past the stop
    is a bitwise no-op, which is what makes chunked stepping equal to the
    one-shot ``lax.while_loop``.
    """
    rcfg = cfg.recolor
    comm = AxisComm(axis, lane_axes)
    n_local_max = arrs["indptr"].shape[0] - 1
    mc = rcfg.max_colors
    K = cfg.n_iters
    assert K >= 1
    kind_ids = jnp.asarray(np.asarray(cfg.kind_ids, np.int32))
    patience = cfg.patience if cfg.patience else K + 1  # K+1 never trips

    # Narrow the traced permutation switch to the kinds the static schedule
    # actually uses: vmap lowers ``lax.switch`` to run-every-branch + select,
    # so a batched (color_many) run would otherwise pay all four rank sorts
    # per iteration per graph.  A single-kind schedule (e.g. pure ND) skips
    # the switch entirely; ND-RAND%x narrows it to two branches.  Each
    # branch is the same static function, so this is bitwise-neutral.
    present = tuple(sorted(set(cfg.kind_ids)))
    if len(present) == 1:
        kind0 = ALL_PERMS[present[0]]
        rank_of = lambda sizes, kid, ikey: permutation_rank(sizes, kind0,
                                                            ikey)
    elif len(present) < len(ALL_PERMS):
        present_arr = jnp.asarray(np.asarray(present, np.int32))
        branches = [lambda s, ky, k=ALL_PERMS[p]: permutation_rank(s, k, ky)
                    for p in present]
        rank_of = lambda sizes, kid, ikey: jax.lax.switch(
            jnp.searchsorted(present_arr, kid).astype(jnp.int32), branches,
            sizes, ikey)
    else:
        rank_of = permutation_rank_traced

    def lane_on(state):
        _, it, _, stall, _, _, _ = state
        return (it <= K) & (stall < patience)

    def body(state):
        view, it, best, stall, hist, sizes, n_oor = state
        # this lane's own adaptive stop: when it has tripped but a batch
        # lane elsewhere on the mesh keeps the loop alive, the body still
        # executes (uniform collectives) and the carry freezes below
        on = lane_on(state)
        ikey = jax.random.fold_in(key, it)           # host loop's per-it key
        kid = kind_ids[it - 1]
        n_classes = jnp.sum(sizes > 0).astype(jnp.int32)
        rank = rank_of(sizes, kid, ikey)
        view, st = recolor_pass_spmd(arrs, view, rank, n_classes, rcfg,
                                     P_size=P_size, plan_static=plan_static,
                                     axis=axis, lane_axes=lane_axes)
        # post-iteration sizes double as the next iteration's schedule input
        # (local slots are final once the iteration ends, so this is bitwise
        # the class_sizes the host loop recomputes at its next call)
        sizes, oor_next = class_sizes(view, arrs["n_local"], n_local_max, mc,
                                      comm)
        nd_after = jnp.sum(sizes > 0).astype(jnp.int32)
        row = jnp.stack([st["n_colors"], nd_after, n_classes,
                         st["n_exchanges"], st["n_steps"], st["wire_bytes"],
                         n_oor, kid, jnp.int32(1)]).astype(jnp.int32)
        hist = jax.lax.dynamic_update_slice(hist, row[None],
                                            (it - 1, jnp.int32(0)))
        improved = nd_after < best
        new_state = (view, it + 1, jnp.minimum(best, nd_after),
                     jnp.where(improved, jnp.int32(0), stall + 1), hist,
                     sizes, oor_next)
        return jax.tree.map(lambda n, o: jnp.where(on, n, o),
                            new_state, state)

    def cond(state):
        return comm.lane_uniform(lane_on(state))

    return body, cond, lane_on


def recolor_carry_init(arrs, view, cfg: PipelineConfig,
                       axis: str = AXIS, lane_axes: tuple = ()):
    """The recolor loop's initial carry from a colored view.

    Carry layout: ``(view, it, best, stall, hist, sizes, n_out_of_range)``
    — ``it`` is 1-based (``it - 1`` iterations have run), ``hist`` the
    device-resident ``(max(K,1), n_stats)`` history.  Feeding this carry
    to ``pipeline_step_spmd`` in chunks replays ``recolor_loop_spmd``
    bitwise; the serving engine holds one such carry per lane.
    """
    comm = AxisComm(axis, lane_axes)
    n_local_max = arrs["indptr"].shape[0] - 1
    K = cfg.n_iters
    hist0 = jnp.zeros((max(K, 1), len(HISTORY_STATS)), jnp.int32)
    sizes0, oor0 = class_sizes(view, arrs["n_local"], n_local_max,
                               cfg.recolor.max_colors, comm)
    return (view, jnp.int32(1), jnp.int32(jnp.iinfo(jnp.int32).max),
            jnp.int32(0), hist0, sizes0, oor0)


def recolor_loop_spmd(arrs, view, key, cfg: PipelineConfig,
                      P_size: int | None = None, plan_static=None,
                      axis: str = AXIS, lane_axes: tuple = ()):
    """K fused recoloring iterations in one ``lax.while_loop`` (per-shard).

    Each iteration folds ``it`` into ``key``, reads its permutation kind
    from the static schedule, and runs ``recolor_pass_spmd`` — bitwise the
    host loop's iteration, minus the host round-trip.  Returns
    ``(view, history (K, n_stats) int32, n_iters_run)``.

    On a 2D ``batch × shard`` mesh (``lane_axes``, DESIGN.md §10) the loop
    runs while *any* batch lane's adaptive stop holds — a recoloring
    iteration is not idempotent, so a lane whose own stop tripped freezes
    its entire carry (view, history, counters) while its body keeps
    executing the mesh-uniform collective sequence for its peers.  This is
    the shard_map form of what ``vmap`` of ``lax.while_loop`` already does
    for same-device lanes (run-to-global-stop + select-mask), so lane
    results stay bitwise the solo run's.
    """
    if cfg.n_iters == 0:
        hist0 = jnp.zeros((1, len(HISTORY_STATS)), jnp.int32)
        return view, hist0, jnp.int32(0)
    body, cond, _ = _recolor_loop_fns(arrs, key, cfg, P_size=P_size,
                                      plan_static=plan_static, axis=axis,
                                      lane_axes=lane_axes)
    state0 = recolor_carry_init(arrs, view, cfg, axis=axis,
                                lane_axes=lane_axes)
    view, it, _, _, hist, _, _ = jax.lax.while_loop(cond, body, state0)
    return view, hist, it - 1


def pipeline_carry_spmd(arrs, order, color_key, cfg: PipelineConfig,
                        P_size: int | None = None, plan_static=None,
                        axis: str = AXIS, lane_axes: tuple = ()):
    """Initial coloring + recolor carry for *stepped* execution (per-shard).

    The front half of ``color_then_recolor``: runs ``color_spmd`` and
    packs the result into a ``recolor_carry_init`` carry instead of
    entering the while loop.  Returns ``(carry, color_stats)`` — advance
    the carry with ``pipeline_step_spmd``.  This is the serving engine's
    lane-admission program (DESIGN.md §11).
    """
    assert cfg.color is not None, "pipeline_carry_spmd needs cfg.color"
    view, cstats = color_spmd(arrs, order, color_key, cfg.color,
                              P_size=P_size, plan_static=plan_static,
                              axis=axis, lane_axes=lane_axes)
    carry = recolor_carry_init(arrs, view, cfg, axis=axis,
                               lane_axes=lane_axes)
    return carry, cstats


def pipeline_step_spmd(arrs, carry, key, cfg: PipelineConfig, chunk: int,
                       P_size: int | None = None, plan_static=None,
                       axis: str = AXIS, lane_axes: tuple = ()):
    """Advance a recolor carry by ``chunk`` fused iterations (per-shard).

    Applies the while loop's *body* a fixed ``chunk`` times
    (``lax.fori_loop`` with static bounds — uniform control flow by
    construction) and returns ``(carry, done)``.  Because the body
    select-freezes a lane whose adaptive stop has tripped, applications
    past the stop are bitwise no-ops: running ``pipeline_step_spmd`` until
    ``done`` yields exactly the carry ``recolor_loop_spmd`` would have
    produced uninterrupted, for any chunk size.  The serving engine
    interleaves lane admission between chunks on this guarantee.
    """
    assert chunk >= 1
    if cfg.n_iters == 0:
        return carry, jnp.bool_(True)
    body, _, lane_on = _recolor_loop_fns(arrs, key, cfg, P_size=P_size,
                                         plan_static=plan_static, axis=axis,
                                         lane_axes=lane_axes)
    carry = jax.lax.fori_loop(0, chunk, lambda _, s: body(s), carry)
    return carry, ~lane_on(carry)


def color_then_recolor(arrs, order, color_key, recolor_key,
                       cfg: PipelineConfig, P_size: int | None = None,
                       plan_static=None, axis: str = AXIS,
                       lane_axes: tuple = ()):
    """The fused pipeline program (per-shard SPMD, jit/shard_map ready).

    Initial speculative coloring + K recoloring iterations, all device
    resident.  ``axis`` names the shard mesh axis of every collective;
    ``lane_axes`` the batch axes of a 2D mesh whose lanes this program's
    control flow must stay uniform over (DESIGN.md §10).
    Returns ``(view, color_stats, history, n_iters_run)``.
    """
    assert cfg.color is not None, "color_then_recolor needs cfg.color"
    view, cstats = color_spmd(arrs, order, color_key, cfg.color,
                              P_size=P_size, plan_static=plan_static,
                              axis=axis, lane_axes=lane_axes)
    view, hist, n_run = recolor_loop_spmd(arrs, view, recolor_key, cfg,
                                          P_size=P_size,
                                          plan_static=plan_static, axis=axis,
                                          lane_axes=lane_axes)
    return view, cstats, hist, n_run


# ----------------------------------------------------------------- drivers --

def _history_to_host(hist) -> list[dict]:
    """(K, n_stats) (or (P, K, n_stats) stacked) device history -> dicts.

    One unpacking at the end of the run — the host loop's per-iteration
    ``stats_to_host`` sync collapsed into a single transfer.  Rows the
    adaptive stop never reached (``ran == 0``) are dropped.
    """
    hist = np.asarray(hist)
    if hist.ndim == 3:                       # (P, K, n_stats) shard stack
        hist = hist.max(axis=0)
    out = []
    for i in range(hist.shape[0]):
        row = {k: int(v) for k, v in zip(HISTORY_STATS, hist[i])}
        if not row.pop("ran"):
            break
        row["perm"] = ALL_PERMS[row.pop("perm_id")]
        row["iteration"] = i + 1
        out.append(row)
    return out


def _plan_static(pg: PartitionedGraph, cfg: PipelineConfig):
    return pg.comm_plan.static if cfg.needs_sparse_plan else None


def _pipeline_arrays(pg: PartitionedGraph, cfg: PipelineConfig) -> dict:
    """Device-resident input dict, cached on the partition instance.

    JAX arrays are immutable, so the same device buffers serve every
    dispatch of this partition — a memoized serving entry pays the
    host->device transfer once, not per warm request.
    """
    cache = pg.__dict__.setdefault("_device_arrays", {})
    sparse = cfg.needs_sparse_plan
    if sparse not in cache:
        cache[sparse] = {k: jnp.asarray(v)
                         for k, v in pg.arrays(sparse=sparse).items()}
    return cache[sparse]


# ------------------------------------------------- compiled-program cache --

@dataclasses.dataclass(frozen=True)
class PlanSignature:
    """Hashable identity of one compiled pipeline program (DESIGN.md §2).

    Two dispatches with equal signatures share one lowered program.  The
    named fields are the readable core (``launch/dryrun.py`` prints them):
    ``rungs`` is the comm plan's static ``(shifts, pow2 widths)`` — the
    part width quantization exists to stabilize — and ``scheme`` is the
    *resolved* exchange scheme (never "auto").  ``dims`` pins every input
    array's ``(name, shape, dtype)`` so signature equality is exactly as
    strict as the jit trace, ``axes`` pins the mesh layout as ``((axis
    name, axis size), ...)`` — two meshes with different axis names or
    shapes lower different collectives, so they must not share a program —
    and ``cfg`` carries the full static config; ``extra`` holds non-array
    trace context (the mesh object, for sharded programs).
    """

    kind: str          # program family: pipe_sim | loop_sim | pipe_sharded
                       # | many_sim | many_sharded
    P: int
    n_local_max: int
    maxd: int
    max_colors: int
    distance: int
    scheme: str        # resolved: "sparse" | "allgather"
    rungs: tuple       # plan static (shifts, pow2 widths); () for allgather
    batch: int         # vmapped graph lanes (0 = solo program)
    cfg: object        # resolved PipelineConfig (trace-static)
    dims: tuple        # ((name, shape, dtype), ...) of every input array
    axes: tuple = ()   # mesh layout ((axis name, axis size), ...)
    extra: object = None

    def describe(self) -> str:
        """The human-readable core (what ``dryrun --coloring`` reports)."""
        axes = "×".join(f"{n}={s}" for n, s in self.axes) or "-"
        return (f"kind={self.kind} P={self.P} "
                f"n_local_max={self.n_local_max} maxd={self.maxd} "
                f"max_colors={self.max_colors} distance={self.distance} "
                f"scheme={self.scheme} batch={self.batch} axes={axes} "
                f"rungs={self.rungs[1] if self.rungs else ()}")


class _ProgramCache:
    """Process-wide LRU of jitted pipeline programs keyed on PlanSignature.

    ``hits``/``misses`` count signature lookups; ``traces`` counts actual
    XLA traces (a Python side effect inside each jitted wrapper, executed
    once per trace) — the regression tests pin ``traces`` so a silently
    widened cache key can't reintroduce retrace-per-graph dispatch.
    """

    def __init__(self, maxsize: int = 128):
        self._fns: OrderedDict = OrderedDict()
        self.maxsize = maxsize
        self.hits = self.misses = self.traces = 0

    def get(self, sig: PlanSignature, build):
        fn = self._fns.get(sig)
        if fn is not None:
            self._fns.move_to_end(sig)
            self.hits += 1
            return fn
        self.misses += 1
        fn = build()
        self._fns[sig] = fn
        while len(self._fns) > self.maxsize:
            self._fns.popitem(last=False)
        return fn

    def clear(self):
        self._fns.clear()
        self.hits = self.misses = self.traces = 0


_PROGRAMS = _ProgramCache()


def program_cache_stats() -> dict:
    """Snapshot of the process-wide program cache counters."""
    return dict(hits=_PROGRAMS.hits, misses=_PROGRAMS.misses,
                traces=_PROGRAMS.traces, size=len(_PROGRAMS._fns))


def program_cache_clear() -> None:
    """Drop every cached program and zero the counters (tests/benchmarks)."""
    _PROGRAMS.clear()


def _count_traces(fn):
    """Increment the trace counter when (and only when) XLA traces ``fn``."""
    def wrapped(*args):
        _PROGRAMS.traces += 1
        return fn(*args)
    return wrapped


def _dims_of(arrs) -> tuple:
    return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                        for k, v in arrs.items()))


def _mesh_axes_or_sim(mesh, P: int) -> tuple:
    """Signature ``axes``: the mesh layout, or the sim executor's implied
    single vmap axis (``run_sim`` binds ``AXIS`` at size P)."""
    return ((AXIS, P),) if mesh is None else mesh_axes(mesh)


def _signature(kind: str, P: int, cfg: PipelineConfig, plan_static, arrs,
               batch: int = 0, extra=None) -> PlanSignature:
    mc = (cfg.color.max_colors if cfg.color is not None
          else cfg.recolor.max_colors)
    return PlanSignature(
        kind=kind, P=P, n_local_max=int(arrs["indptr"].shape[-1]) - 1,
        maxd=int(arrs["nbr"].shape[-1]), max_colors=mc,
        distance=cfg.recolor.distance, scheme=cfg.recolor.scheme,
        rungs=plan_static if plan_static is not None else (),
        batch=batch, cfg=cfg, dims=_dims_of(arrs),
        axes=_mesh_axes_or_sim(extra, P), extra=extra)


def resolve_pipeline_cfg(pg: PartitionedGraph,
                         cfg: PipelineConfig) -> PipelineConfig:
    """Concretize any ``scheme="auto"`` stage against ``pg``'s comm plan.

    The decision (``comm.resolve_scheme``) compares the *padded* sparse
    plan bytes — what the compiled program physically ships — against the
    broadcast's; an explicit scheme passes through untouched.
    """
    if not cfg.has_auto:
        return cfg
    return dataclasses.replace(
        cfg, color=None if cfg.color is None else resolve_cfg(pg, cfg.color),
        recolor=resolve_cfg(pg, cfg.recolor))


def plan_signature(pg: PartitionedGraph, cfg: PipelineConfig, *,
                   kind: str | None = None, batch: int = 0,
                   mesh=None) -> PlanSignature:
    """The signature a ``pipeline_sim``-family dispatch of ``pg`` would use.

    Public inspection hook (``launch/dryrun.py``, the serving cost model,
    tests): resolves "auto", builds the device dict host-side and derives
    the exact cache key without compiling anything.  ``mesh`` selects the
    ``pipeline_sharded`` program (``kind`` defaults accordingly).
    """
    if kind is None:
        kind = "pipe_sim" if mesh is None else "pipe_sharded"
    cfg = resolve_pipeline_cfg(pg, cfg)
    arrs = pg.arrays(sparse=cfg.needs_sparse_plan)
    return _signature(kind, pg.P, cfg, _plan_static(pg, cfg), arrs,
                      batch=batch, extra=mesh)


def program_cache_contains(sig: PlanSignature) -> bool:
    """Cache-probe for the serving cost model — no counter side effects."""
    return sig in _PROGRAMS._fns


def bucket_signature(bucket, cfg: PipelineConfig, *, pad_batch: bool = True,
                     mesh=None) -> PlanSignature:
    """The signature a ``color_many``(`_sharded``) dispatch of ``bucket``
    would use.

    The serving driver's cost model probes the program cache with this
    before deciding solo-vs-batch routing; nothing is stacked or compiled —
    batch padding and the sharded layout's axis swap are applied to shapes
    only.
    """
    bcfg = _resolve_bucket_cfg(bucket, cfg)
    ma = bucket.member_arrays(0, sparse=bcfg.needs_sparse_plan)
    lane_multiple = batch_axis_size(mesh) if mesh is not None else 1
    B = _lane_target(bucket.B, pad_batch, lane_multiple)

    def dim(v):
        s = (B,) + tuple(v.shape)
        return (s[1], s[0]) + s[2:] if mesh is not None else s

    dims = tuple(sorted((k, dim(v), str(np.asarray(v).dtype))
                        for k, v in ma.items()))
    ps = bucket.plan_static if bcfg.needs_sparse_plan else None
    mc = (bcfg.color.max_colors if bcfg.color is not None
          else bcfg.recolor.max_colors)
    return PlanSignature(
        kind="many_sim" if mesh is None else "many_sharded", P=bucket.P,
        n_local_max=bucket.members[0].n_local_max,
        maxd=bucket.members[0].maxd, max_colors=mc,
        distance=bcfg.recolor.distance, scheme=bcfg.recolor.scheme,
        rungs=ps if ps is not None else (), batch=B, cfg=bcfg, dims=dims,
        axes=_mesh_axes_or_sim(mesh, bucket.P), extra=mesh)


def _bucket_scheme(bucket) -> str:
    """Trace-time sparse-vs-allgather pick for one bucket (union plan)."""
    sparse_b = sum(bucket.plan_static[1]) * 4
    ag_b = allgather_bytes_per_exchange(bucket.P,
                                        bucket.members[0].max_boundary)
    return SPARSE if sparse_b <= ag_b else ALLGATHER


def _resolve_bucket_cfg(bucket, cfg: PipelineConfig) -> PipelineConfig:
    """Per-bucket "auto" resolution: members share one compiled program, so
    the decision is made once from the union plan's padded bytes."""
    if not cfg.has_auto:
        return cfg
    scheme = _bucket_scheme(bucket)
    fix = lambda c: (None if c is None else
                     dataclasses.replace(c, scheme=scheme)
                     if c.scheme == AUTO else c)
    return dataclasses.replace(cfg, color=fix(cfg.color),
                               recolor=fix(cfg.recolor))


def recolor_loop_sim(pg: PartitionedGraph, view, cfg: PipelineConfig,
                     key=None):
    """Fused recolor-only loop (sim executor): ``recolor_iterations``' core.

    Returns ``(view, history list-of-dicts, n_iters_run)``.
    """
    cfg = resolve_pipeline_cfg(pg, cfg)
    arrs = _pipeline_arrays(pg, cfg)
    if key is None:
        key = jax.random.key(cfg.seed)
    ps = _plan_static(pg, cfg)
    sig = _signature("loop_sim", pg.P, cfg, ps, arrs)

    def build(P=pg.P):
        fn = partial(recolor_loop_spmd, cfg=cfg, P_size=P, plan_static=ps)
        return jax.jit(_count_traces(
            lambda arrs, view, key: run_sim(fn, P, (arrs, view), (key,))))

    view, hist, n_run = _PROGRAMS.get(sig, build)(arrs, jnp.asarray(view),
                                                  key)
    hist, n_run = jax.device_get((hist, n_run))     # one host transfer
    return view, _history_to_host(hist), int(np.max(n_run))


def _keys(cfg: PipelineConfig, color_key, recolor_key):
    if color_key is None:
        color_key = jax.random.key(cfg.color.seed)
    if recolor_key is None:
        recolor_key = jax.random.key(cfg.seed)
    return color_key, recolor_key


def _pipeline_result(view, cstats, hist, n_run):
    # shard-max the stats on device, then cross to the host once: stats,
    # history and iteration count ride a single device_get
    cmax = {k: jnp.max(v) for k, v in cstats.items()}
    cmax, hist, n_run = jax.device_get((cmax, hist, n_run))
    return view, dict(color={k: int(v) for k, v in cmax.items()},
                      history=_history_to_host(hist),
                      n_iters_run=int(np.max(n_run)))


def pipeline_sim(pg: PartitionedGraph, order, cfg: PipelineConfig, *,
                 marked=None, color_key=None, recolor_key=None):
    """Run the fused pipeline *simulated* on one device (P vmap lanes).

    ``order``/``marked`` as ``color_graph_sim``; ``color_key`` /
    ``recolor_key`` default to ``key(cfg.color.seed)`` / ``key(cfg.seed)``.
    Returns ``(view, result)``: ``view`` is the final ``(P, n_slots)``
    device view and ``result`` holds the initial-coloring stats
    (``"color"``, keys as ``color_graph_sim``), the per-iteration
    ``"history"`` (one dict per executed iteration, keys as
    ``recolor_sim`` plus ``perm``/``iteration``) and ``"n_iters_run"``
    (adaptive stop included).  ``pipeline_sharded`` is the
    bitwise-identical ``workers``-mesh variant.
    """
    assert cfg.color is not None, "pipeline_sim needs cfg.color"
    cfg = resolve_pipeline_cfg(pg, cfg)
    arrs = _pipeline_arrays(pg, cfg)
    order = _apply_partial(order, cfg.color, marked)
    ck, rk = _keys(cfg, color_key, recolor_key)
    ps = _plan_static(pg, cfg)
    sig = _signature("pipe_sim", pg.P, cfg, ps, arrs)

    def build(P=pg.P):
        fn = partial(color_then_recolor, cfg=cfg, P_size=P, plan_static=ps)
        return jax.jit(_count_traces(
            lambda arrs, order, ck, rk: run_sim(fn, P, (arrs, order),
                                                (ck, rk))))

    out = _PROGRAMS.get(sig, build)(arrs, jnp.asarray(order), ck, rk)
    return _pipeline_result(*out)


def pipeline_sharded(pg: PartitionedGraph, order, cfg: PipelineConfig, mesh,
                     *, marked=None, color_key=None, recolor_key=None):
    """Run the fused pipeline on a real mesh shard axis
    (``shard_axis_of(mesh)``) via shard_map; on a 2D ``batch × shard``
    mesh the solo graph is replicated over the batch axis."""
    assert cfg.color is not None, "pipeline_sharded needs cfg.color"
    cfg = resolve_pipeline_cfg(pg, cfg)
    arrs = _pipeline_arrays(pg, cfg)
    order = _apply_partial(order, cfg.color, marked)
    ck, rk = _keys(cfg, color_key, recolor_key)
    ps = _plan_static(pg, cfg)
    sig = _signature("pipe_sharded", pg.P, cfg, ps, arrs, extra=mesh)

    def build(P=pg.P):
        axis = shard_axis_of(mesh)
        fn = partial(color_then_recolor, cfg=cfg, P_size=P, plan_static=ps,
                     axis=axis)
        return jax.jit(_count_traces(
            lambda a, o, k1, k2: run_sharded(fn, mesh, (a, o), (k1, k2),
                                             axis=axis)))

    out = _PROGRAMS.get(sig, build)(arrs, jnp.asarray(order), ck, rk)
    return _pipeline_result(*out)


# ------------------------------------------- batched multi-graph pipeline --

def _many_sim_program(sig, P, cfg, plan_static):
    """One jitted program per signature: vmap over graphs of vmap over
    shards — reused across batches (and graphs) through ``_PROGRAMS``."""
    def build():
        fn = partial(color_then_recolor, cfg=cfg, P_size=P,
                     plan_static=plan_static)
        inner = lambda arrs, order, ck, rk: run_sim(fn, P, (arrs, order),
                                                    (ck, rk))
        return jax.jit(_count_traces(jax.vmap(inner)))
    return _PROGRAMS.get(sig, build)


def _many_sharded_program(sig, P, cfg, plan_static, mesh):
    """Cached mesh dispatch — without it every flush would rebuild the
    vmap/jit wrappers and recompile, defeating the pow2 shape bucketing
    the serving path relies on.

    On a 2D ``batch × shard`` mesh the graph lanes are *sharded* over the
    batch axis (``run_sharded_many``): each device vmaps only its B/Bm
    lanes, and the per-graph RNG keys ride as batch-sharded lane args.  On
    a 1D mesh this degenerates to the classic vmap-inside-shard_map."""
    def build():
        axis = shard_axis_of(mesh)
        baxis = batch_axis_of(mesh)
        lane_axes = (baxis,) if baxis is not None else ()
        fn = jax.vmap(partial(color_then_recolor, cfg=cfg, P_size=P,
                              plan_static=plan_static, axis=axis,
                              lane_axes=lane_axes))
        return jax.jit(_count_traces(
            lambda a, o, k1, k2: run_sharded_many(fn, mesh, (a, o),
                                                  (k1, k2), axis=axis)))
    return _PROGRAMS.get(sig, build)


# ----------------------------------------------- continuous-engine programs --

def engine_init_program(P: int, cfg: PipelineConfig, plan_static, arrs,
                        mesh=None):
    """Cached single-lane admission program for the serving engine.

    ``(arrs, order, color_key) -> (carry, color_stats)`` — initial coloring
    packed into a recolor carry (``pipeline_carry_spmd``).  ``arrs`` is the
    lane's host- or device-side input dict, used for the cache signature;
    the engine runs this once per admitted request and scatters the result
    into its lane buffers, so admission never recompiles (DESIGN.md §11).
    """
    assert not cfg.has_auto
    sig = _signature("engine_init", P, cfg, plan_static, arrs, extra=mesh)

    def build():
        if mesh is None:
            fn = partial(pipeline_carry_spmd, cfg=cfg, P_size=P,
                         plan_static=plan_static)
            return jax.jit(_count_traces(
                lambda a, o, ck: run_sim(fn, P, (a, o), (ck,))))
        axis = shard_axis_of(mesh)
        fn = partial(pipeline_carry_spmd, cfg=cfg, P_size=P,
                     plan_static=plan_static, axis=axis)
        return jax.jit(_count_traces(
            lambda a, o, ck: run_sharded(fn, mesh, (a, o), (ck,),
                                         axis=axis)))

    return _PROGRAMS.get(sig, build)


def engine_step_program(P: int, cfg: PipelineConfig, plan_static, arrs,
                        B: int, chunk: int, mesh=None):
    """Cached all-lanes step program for the serving engine.

    ``(arrs, carry, keys) -> (carry, done)`` — every lane advances by
    ``chunk`` fused recoloring iterations (``pipeline_step_spmd`` vmapped
    over the B lane axis), with the carry input buffers **donated**: the
    engine owns exactly one generation of lane state at a time.  Sim
    layout stacks lanes on axis 0 (``(B, P, ...)``, ``done (B, P)``); on a
    mesh the lanes ride ``run_sharded_many``'s ``(P, B, ...)`` layout
    (``done (P, B)``) and are sharded over the batch mesh axis.  Lanes
    whose stop has tripped (or that are empty) are frozen by the body's
    select-mask, so a partially idle engine steps bitwise-inertly.
    """
    assert not cfg.has_auto
    sig = _signature(f"engine_step{chunk}", P, cfg, plan_static, arrs,
                     batch=B, extra=mesh)

    def build():
        if mesh is None:
            fn = partial(pipeline_step_spmd, cfg=cfg, chunk=chunk, P_size=P,
                         plan_static=plan_static)
            inner = lambda a, c, k: run_sim(fn, P, (a, c), (k,))
            return jax.jit(_count_traces(jax.vmap(inner)),
                           donate_argnums=(1,))
        axis = shard_axis_of(mesh)
        baxis = batch_axis_of(mesh)
        lane_axes = (baxis,) if baxis is not None else ()
        fn = jax.vmap(partial(pipeline_step_spmd, cfg=cfg, chunk=chunk,
                              P_size=P, plan_static=plan_static, axis=axis,
                              lane_axes=lane_axes))
        return jax.jit(_count_traces(
            lambda a, c, k: run_sharded_many(fn, mesh, (a, c), (k,),
                                             axis=axis)),
            donate_argnums=(1,))

    return _PROGRAMS.get(sig, build)


def engine_put_program(P: int, cfg: PipelineConfig, plan_static, arrs,
                       B: int, mesh=None):
    """Cached lane-scatter program for the serving engine.

    ``(bufs, vals, b) -> bufs`` — write one admitted lane's arrays/carry/
    stats (``vals``, unstacked) into lane ``b`` of the engine's stacked
    buffers in ONE donated dispatch.  Eagerly scattering the ~30 buffers
    one ``.at[b].set`` at a time costs a device round-trip per buffer and
    dominates admission latency; this program is the whole swap.  ``b``
    is a traced operand, so every lane shares the one compiled program.
    """
    assert not cfg.has_auto
    sig = _signature("engine_put", P, cfg, plan_static, arrs, batch=B,
                     extra=mesh)
    lane_axis = 0 if mesh is None else 1

    def build():
        def put(bufs, vals, b):
            return jax.tree.map(
                lambda buf, v: jax.lax.dynamic_update_index_in_dim(
                    buf, v, b, axis=lane_axis), bufs, vals)
        return jax.jit(_count_traces(put), donate_argnums=(0,))

    return _PROGRAMS.get(sig, build)


def _keys_many(cfg: PipelineConfig, n, color_keys, recolor_keys):
    """Per-graph key lists: defaults fold the graph's input position into
    the config seeds, so every graph gets an independent stream and a solo
    rerun with the same folded key reproduces its lane bitwise."""
    if color_keys is None:
        base = jax.random.key(cfg.color.seed)
        color_keys = [jax.random.fold_in(base, i) for i in range(n)]
    if recolor_keys is None:
        base = jax.random.key(cfg.seed)
        recolor_keys = [jax.random.fold_in(base, i) for i in range(n)]
    assert len(color_keys) == n and len(recolor_keys) == n
    return list(color_keys), list(recolor_keys)


def _bucket_order(bucket, cfg: PipelineConfig, orders, marked):
    """(B, P, n_local_max) visit order for one bucket's members.

    ``orders`` is an ordering-kind string (computed per padded member —
    identical to padding the original's order, local slots are unchanged)
    or a per-graph sequence of ``(P, n_local_max)`` arrays padded here with
    -1 to the bucket width.  ``marked`` masks are padded with False.

    Kind-string orders with no ``marked`` masks are cached on the bucket:
    a memoized serving bucket must not recompute orders per warm request.
    """
    cache = key = None
    if marked is None and (orders is None or isinstance(orders, str)):
        key = (orders, cfg.color)
        cache = bucket.__dict__.setdefault("_order_cache", {})
        if key in cache:
            return cache[key]
    rows = []
    for j, gi in enumerate(bucket.indices):
        m = bucket.members[j]
        if orders is None or isinstance(orders, str):
            o = compute_order(m, orders or ordering.INTERNAL_FIRST)
        else:
            o = np.asarray(orders[gi])
            o = np.pad(o, ((0, 0), (0, m.n_local_max - o.shape[1])),
                       constant_values=-1)
        mk = None if marked is None else marked[gi]
        if mk is not None:
            mk = np.asarray(mk, dtype=bool)
            mk = np.pad(mk, ((0, 0), (0, m.n_local_max - mk.shape[1])))
        rows.append(_apply_partial(o, cfg.color, mk))
    out = np.stack(rows)
    if cache is not None:
        cache[key] = out
    return out


def _lane_target(B: int, pad_batch: bool, lane_multiple: int = 1) -> int:
    """Padded lane count: pow2 under ``pad_batch``, and always a multiple
    of ``lane_multiple`` (the batch mesh axis size — a 2D mesh shards the
    lane axis, so it must divide evenly)."""
    t = _ceil_pow2(B) if pad_batch else B
    return -(-t // lane_multiple) * lane_multiple


def _pad_batch_lanes(st, order_b, cks_b, rks_b, B, target):
    """Pad the batch axis up to ``target`` lanes with dummy lanes.

    The extra lanes replicate member 0 (lanes are independent, results are
    dropped on unpacking), so a service's batch programs see pow2 batch
    shapes only and keep hitting the jit cache as queue depth fluctuates.
    """
    ext = target - B
    if ext:
        st = {k: np.concatenate([v, np.repeat(v[:1], ext, axis=0)])
              for k, v in st.items()}
        order_b = np.concatenate(
            [order_b, np.repeat(order_b[:1], ext, axis=0)])
        cks_b = cks_b + [cks_b[0]] * ext
        rks_b = rks_b + [rks_b[0]] * ext
    return st, order_b, cks_b, rks_b


def _bucket_inputs(bucket, cfg, orders, marked, cks, rks, pad_batch,
                   lane_multiple: int = 1):
    """Per-bucket dispatch inputs, shared by the sim and sharded drivers."""
    st = bucket.stacked_arrays(sparse=cfg.needs_sparse_plan)
    order_b = _bucket_order(bucket, cfg, orders, marked)
    cks_b = [cks[i] for i in bucket.indices]
    rks_b = [rks[i] for i in bucket.indices]
    st, order_b, cks_b, rks_b = _pad_batch_lanes(
        st, order_b, cks_b, rks_b, bucket.B,
        _lane_target(bucket.B, pad_batch, lane_multiple))
    ps = bucket.plan_static if cfg.needs_sparse_plan else None
    return st, order_b, cks_b, rks_b, ps


def _unpack_bucket(out, bucket, bi, pgs, results):
    """(B, P, ...) batch outputs -> per-graph result dicts (input order)."""
    # every per-graph output crosses to the host in one device_get
    view, cstats, hist, n_run = jax.device_get(out)
    for j, gi in enumerate(bucket.indices):
        v = view[j]
        results[gi] = dict(
            view=v,
            colors=pgs[gi].gather_global_colors(
                v[:, :bucket.members[j].n_local_max]),
            color={k: int(a[j].max()) for k, a in cstats.items()},
            history=_history_to_host(hist[j]),
            n_iters_run=int(n_run[j].max()),
            bucket=bi)
    return results


def color_many(pgs, cfg: PipelineConfig, *, orders=None, marked=None,
               color_keys=None, recolor_keys=None, buckets=None,
               pad_batch: bool = False):
    """Color a batch of partitioned graphs through one fused program each
    bucket (sim executor) — the batched service's dispatch core.

    ``pgs`` — same-``P`` ``PartitionedGraph`` list (``halo`` per
    ``cfg``'s distance).  ``orders`` — an ``ordering`` kind string (default
    ``internal_first``) or per-graph ``(P, n_local_max)`` arrays.
    ``marked`` — per-graph partial-coloring masks (``cfg.color.partial``).
    ``color_keys``/``recolor_keys`` — per-graph JAX keys; the default folds
    each graph's input position into the config seeds.  ``buckets`` — a
    precomputed ``bucket_graphs(pgs)`` result (a server that already
    bucketed its queue passes it to skip the host-side re-pad).
    ``pad_batch=True`` rounds every bucket's batch axis up to a power of
    two with dropped dummy lanes, so batch-program shapes stay stable as
    queue depth fluctuates (jit-cache friendly serving).

    Returns one dict per input graph (input order): ``view`` ``(P,
    n_slots)`` padded device view, ``colors`` ``(n_global,)`` 1-based,
    ``color`` initial-coloring stats, ``history``/``n_iters_run`` as
    ``pipeline_sim``, and the ``bucket`` index.  Each graph's view and
    history are bitwise a solo ``pipeline_sim`` run on its padded member
    (``bucket.members[j]``) with the same keys.
    """
    assert cfg.color is not None, "color_many needs cfg.color"
    pgs = list(pgs)
    if buckets is None:
        buckets = bucket_graphs(pgs)
    cks, rks = _keys_many(cfg, len(pgs), color_keys, recolor_keys)
    results = [None] * len(pgs)
    for bi, bucket in enumerate(buckets):
        bcfg = _resolve_bucket_cfg(bucket, cfg)
        st, order_b, cks_b, rks_b, ps = _bucket_inputs(
            bucket, bcfg, orders, marked, cks, rks, pad_batch)
        sig = _signature("many_sim", bucket.P, bcfg, ps, st,
                         batch=len(cks_b))
        out = _many_sim_program(sig, bucket.P, bcfg, ps)(
            {k: jnp.asarray(v) for k, v in st.items()},
            jnp.asarray(order_b), jnp.stack(cks_b), jnp.stack(rks_b))
        _unpack_bucket(out, bucket, bi, pgs, results)
    return results


def color_many_sharded(pgs, cfg: PipelineConfig, mesh, *, orders=None,
                       marked=None, color_keys=None, recolor_keys=None,
                       buckets=None, pad_batch: bool = False):
    """``color_many`` on a real mesh: collectives run over the mesh's
    shard axis (``shard_axis_of``).  On a 1D mesh the graph batch axis
    rides *inside* each shard (vmap under shard_map); on a 2D ``batch ×
    shard`` mesh (``make_coloring_mesh(P, batch=Bm)``) the lanes are
    additionally sharded over the batch axis — each device vmaps B/Bm
    lanes, and lane counts are padded to a multiple of Bm.  Either way
    every per-graph result is bitwise the sim executor's."""
    assert cfg.color is not None, "color_many_sharded needs cfg.color"
    pgs = list(pgs)
    if buckets is None:
        buckets = bucket_graphs(pgs)
    cks, rks = _keys_many(cfg, len(pgs), color_keys, recolor_keys)
    results = [None] * len(pgs)
    for bi, bucket in enumerate(buckets):
        bcfg = _resolve_bucket_cfg(bucket, cfg)
        st, order_b, cks_b, rks_b, ps = _bucket_inputs(
            bucket, bcfg, orders, marked, cks, rks, pad_batch,
            lane_multiple=batch_axis_size(mesh))
        # leading axis P for shard_map; per-shard arrays carry (B, ...)
        arrs = {k: jnp.moveaxis(jnp.asarray(v), 0, 1) for k, v in st.items()}
        order_b = jnp.moveaxis(jnp.asarray(order_b), 0, 1)
        sig = _signature("many_sharded", bucket.P, bcfg, ps, arrs,
                         batch=len(cks_b), extra=mesh)
        out = _many_sharded_program(sig, bucket.P, bcfg, ps, mesh)(
            arrs, order_b, jnp.stack(cks_b), jnp.stack(rks_b))
        # outputs carry (P, B, ...): put the graph axis back in front
        out = jax.tree.map(lambda x: np.moveaxis(np.asarray(x), 0, 1), out)
        _unpack_bucket(out, bucket, bi, pgs, results)
    return results
