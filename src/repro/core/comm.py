"""Communication abstraction: one SPMD code path, two executors.

Algorithms in this package are written as *per-shard* SPMD functions that
communicate exclusively through ``AxisComm`` (named-axis collectives). They
can then run

- **simulated** on a single device via ``jax.vmap(..., axis_name=AXIS)`` —
  used for the paper's quality/scaling studies (P up to 512 simulated
  processors on one CPU), and
- **sharded** on a real device mesh via ``jax.shard_map`` — the production
  path; the multi-pod dry-run lowers exactly this.

This mirrors the paper's MPI structure: an all-gather of boundary-only
payloads replaces neighbour-to-neighbour boundary messages (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

AXIS = "workers"


@dataclasses.dataclass(frozen=True)
class AxisComm:
    """Named-axis collectives used by the coloring SPMD kernels."""

    axis: str = AXIS

    def psum(self, x):
        return jax.lax.psum(x, self.axis)

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis)

    def all_gather(self, x):
        """per-shard (…,) -> (P, …) table, identical on every shard."""
        return jax.lax.all_gather(x, self.axis)

    def index(self):
        return jax.lax.axis_index(self.axis)


def run_sim(fn, P_size: int, sharded_args: tuple, broadcast_args: tuple = ()):
    """Execute SPMD `fn` on ONE device by vmapping over the leading P axis.

    ``sharded_args`` carry a leading axis of size ``P_size``; ``broadcast_args``
    are replicated. `fn(*sharded, *broadcast)` must only communicate via
    ``AxisComm``.
    """
    in_axes = tuple(0 for _ in sharded_args) + tuple(None for _ in broadcast_args)
    return jax.vmap(fn, in_axes=in_axes, axis_name=AXIS,
                    axis_size=P_size)(*sharded_args, *broadcast_args)


def run_sharded(fn, mesh, sharded_args: tuple, broadcast_args: tuple = ()):
    """Execute SPMD `fn` over a real mesh axis ``workers`` via shard_map."""

    def wrapped(*args):
        ns = len(sharded_args)
        sh = [jax.tree.map(lambda x: x[0], a) for a in args[:ns]]
        out = fn(*sh, *args[ns:])
        return jax.tree.map(lambda x: x[None], out)

    in_specs = tuple(P(AXIS) for _ in sharded_args) + tuple(
        P() for _ in broadcast_args)
    # check_vma=False: loop carries (color views, bitsets) legitimately start
    # replicated and become worker-varying after the first exchange.
    return jax.shard_map(wrapped, mesh=mesh, in_specs=in_specs,
                         out_specs=P(AXIS), check_vma=False)(
                             *sharded_args, *broadcast_args)


def exchange_boundary(view: jnp.ndarray, boundary: jnp.ndarray,
                      ghost_owner: jnp.ndarray, ghost_slot: jnp.ndarray,
                      n_local_max: int, comm: AxisComm,
                      wire_dtype=None) -> jnp.ndarray:
    """One boundary-color exchange (the superstep / color-step barrier).

    Ships only boundary colors: payload (max_b,), all-gathered to (P, max_b);
    ghost slots refresh with one gather. This is the collective realization of
    the paper's boundary messages. ``wire_dtype=jnp.int16`` halves the ICI
    bytes (colors are bounded by max_colors <= 32767, config-asserted) — a
    beyond-paper optimization; see DESIGN.md §5 and the collective byte
    counts recorded by ``launch/dryrun.py --coloring``.
    """
    payload = view[boundary]                      # (max_b,)
    if wire_dtype is not None:
        payload = payload.astype(wire_dtype)
    table = comm.all_gather(payload)              # (P, max_b)
    ghosts = table[ghost_owner, ghost_slot]       # (max_g,)
    return jax.lax.dynamic_update_slice(view, ghosts.astype(view.dtype),
                                        (n_local_max,))
