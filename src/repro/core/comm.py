"""Communication abstraction: one SPMD code path, two executors, two schemes.

Algorithms in this package are written as *per-shard* SPMD functions that
communicate exclusively through ``AxisComm`` (named-axis collectives). They
can then run

- **simulated** on a single device via ``jax.vmap(..., axis_name=AXIS)`` —
  used for the paper's quality/scaling studies (P up to 512 simulated
  processors on one CPU), and
- **sharded** on a real device mesh via ``shard_map`` — the production
  path; the multi-pod dry-run lowers exactly this.

Two interchangeable boundary-exchange schemes (``CommConfig.scheme``) produce
bitwise-identical colorings:

- ``"allgather"`` — every shard broadcasts its whole boundary payload; the
  ghost refresh gathers from the (P, max_b) table.  O(P·max_b) wire bytes per
  exchange regardless of which cross edges exist.
- ``"sparse"`` — the paper's neighbour-to-neighbour scheme: a static round
  schedule of ``ppermute`` hops (one per *ring shift* with any traffic, see
  ``graph.CommPlan``) ships each destination only the boundary colors its
  ghosts actually read.  Wire bytes scale with the realized cross-edge
  structure, not with P; a graph with zero cross edges performs zero rounds.

Every exchange returns the per-shard wire bytes it shipped (a traced scalar
accumulated through the drivers' loop carries), so benchmarks and
``launch/dryrun.py`` report *measured* comm volume next to the modeled one.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

AXIS = "workers"        # default shard (graph-partition) mesh axis
BATCH_AXIS = "batch"    # graph-batch mesh axis of 2D batch×shard meshes

ALLGATHER = "allgather"
SPARSE = "sparse"
SCHEMES = (ALLGATHER, SPARSE)          # the two concrete exchange programs
AUTO = "auto"                          # resolve at trace time from the plan
SCHEME_CHOICES = SCHEMES + (AUTO,)

# Default exchange scheme for every config that does not set one explicitly.
# The default is AUTO: the drivers pick sparse vs allgather per graph at
# trace time from the modeled bytes (``resolve_scheme``) — the two schemes
# produce bitwise-identical colorings, so the choice is a pure cost call
# and the user flag is an override.  REPRO_SCHEME drives the CI matrix: the
# tier-1 suite runs once per scheme so both exchange paths (and the auto
# resolution itself) stay covered per push.
DEFAULT_SCHEME = os.environ.get("REPRO_SCHEME", AUTO)
assert DEFAULT_SCHEME in SCHEME_CHOICES, (
    f"REPRO_SCHEME={DEFAULT_SCHEME!r} invalid, want one of {SCHEME_CHOICES}")


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Static configuration of the boundary exchange."""

    scheme: str = DEFAULT_SCHEME   # "allgather" | "sparse" | "auto"
    wire16: bool = False           # int16 payloads (half the wire bytes)

    def __post_init__(self):
        assert self.scheme in SCHEME_CHOICES, f"bad scheme {self.scheme!r}"

    @property
    def wire_dtype(self):
        return jnp.int16 if self.wire16 else None

    @property
    def itemsize(self) -> int:
        return 2 if self.wire16 else 4


@dataclasses.dataclass(frozen=True)
class AxisComm:
    """Named-axis collectives used by the coloring SPMD kernels.

    ``axis`` is the shard (graph-partition) axis every data collective runs
    over.  ``lane_axes`` names *additional* mesh axes the program's control
    flow must be uniform over — on a 2D ``batch × shard`` mesh, graph lanes
    on different batch rows take data-dependent trip counts and exchange
    decisions, but one SPMD program spans the whole mesh, so every device
    must execute the same collective sequence.  ``lane_uniform`` widens an
    already shard-uniform control value (a loop bound, an exchange
    predicate) across the lane axes; each lane then *applies* the effect
    under its own local predicate, keeping results bitwise the solo run's
    (DESIGN.md §10).  With no lane axes (sim, 1-axis meshes) it compiles
    to nothing, so those programs are unchanged.
    """

    axis: str = AXIS
    lane_axes: tuple = ()

    def psum(self, x):
        return jax.lax.psum(x, self.axis)

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis)

    def pmin(self, x):
        return jax.lax.pmin(x, self.axis)

    def all_gather(self, x):
        """per-shard (…,) -> (P, …) table, identical on every shard."""
        return jax.lax.all_gather(x, self.axis)

    def ppermute(self, x, perm):
        """Point-to-point shuffle along the axis (source, dest) pairs."""
        return jax.lax.ppermute(x, self.axis, perm)

    def index(self):
        return jax.lax.axis_index(self.axis)

    def lane_uniform(self, x):
        """Max-reduce a shard-uniform control value over the lane axes.

        Identity when the mesh has none (``lane_axes == ()``); otherwise
        the mesh-wide bound/predicate every device agrees to execute
        under (bools reduce as "any lane needs it").  Only *execution* is
        widened — callers mask per-lane application with the lane's own
        local value so lane results stay bitwise.
        """
        return jax.lax.pmax(x, self.lane_axes) if self.lane_axes else x


def shard_axis_of(mesh) -> str:
    """The mesh axis the coloring core shards graph partitions over.

    The axis-name contract (DESIGN.md §10): a ``workers`` axis always wins;
    otherwise the single non-``batch`` axis; otherwise (degenerate smoke
    meshes where every axis has size 1, e.g. ``make_local_mesh``) the last
    axis.  Ambiguous multi-axis meshes raise — the caller must build its
    mesh through ``launch.mesh.MeshSpec`` so the names are explicit.
    """
    names = tuple(mesh.axis_names)
    if AXIS in names:
        return AXIS
    cands = [n for n in names if n != BATCH_AXIS]
    if len(cands) == 1:
        return cands[0]
    sized = [n for n in cands if int(mesh.shape[n]) > 1]
    if len(sized) == 1:
        return sized[0]
    if cands and not sized:          # all-size-1 smoke mesh: any axis works
        return cands[-1]
    raise ValueError(
        f"cannot infer the shard axis of mesh axes {names}: none is named "
        f"{AXIS!r} and {len(sized)} non-{BATCH_AXIS!r} axes have size > 1; "
        f"build the mesh via launch.mesh.MeshSpec")


def batch_axis_of(mesh) -> str | None:
    """The graph-batch axis of a 2D ``batch × shard`` mesh (None if 1D)."""
    return BATCH_AXIS if BATCH_AXIS in tuple(mesh.axis_names) else None


def batch_axis_size(mesh) -> int:
    """Size of the graph-batch mesh axis (1 when the mesh has none)."""
    b = batch_axis_of(mesh)
    return int(mesh.shape[b]) if b is not None else 1


def mesh_axes(mesh) -> tuple:
    """Hashable ``((axis name, axis size), ...)`` — the program-cache key
    component that pins which mesh geometry a sharded program was traced
    for (``pipeline.PlanSignature.axes``)."""
    return tuple((n, int(s)) for n, s in zip(mesh.axis_names,
                                             mesh.devices.shape))


def shard_uniform(x):
    """Identity marker: assert-by-contract that ``x`` is shard-uniform.

    Some values are uniform by *contract* rather than by construction — a
    round mask computed from a pmax-reduced schedule and passed down as a
    plain parameter, or a class count every caller derives from globally
    psum-reduced sizes.  Wrapping them in ``shard_uniform`` documents the
    contract at the consumption site and lets repro-lint's
    ``divergent-collective``/``nonuniform-loop`` rules (DESIGN.md §9)
    treat the value as uniform instead of demanding a redundant collective.
    It compiles to nothing (returns its argument unchanged).
    """
    return x


def allgather_bytes_per_exchange(P_size: int, max_boundary: int,
                                 itemsize: int = 4) -> int:
    """Per-shard wire bytes of one broadcast exchange (ring all-gather:
    every shard receives the other P-1 payloads of max_b entries).  The one
    home of the all-gather cost model — the sparse counterpart lives in
    ``graph.CommPlan.bytes_per_exchange``."""
    return (P_size - 1) * max_boundary * itemsize


def resolve_scheme(scheme: str, pg) -> str:
    """The trace-time sparse-vs-allgather decision (DESIGN.md §2).

    ``scheme`` other than ``"auto"`` is a user override and returns as-is.
    ``"auto"`` picks whichever exchange *physically ships* fewer bytes for
    this partition: the sparse plan's padded (pow2-rung) buffer widths —
    what the compiled ``ppermute`` rounds actually put on the wire —
    against the ring all-gather's ``(P-1)·max_b``.  Both schemes produce
    bitwise-identical colorings, so this is a pure cost decision; the
    result lands in the program's ``PlanSignature``/jit key, never in user
    config.  Ties go to sparse (fewer bytes *accounted* too, and zero
    rounds on cross-edge-free partitions).
    """
    if scheme != AUTO:
        return scheme
    sparse_b = pg.comm_plan.bytes_per_exchange(padded=True)
    return SPARSE if sparse_b <= allgather_bytes_per_exchange(
        pg.P, pg.max_boundary) else ALLGATHER


def stats_to_host(stats) -> dict:
    """Device stats dict -> python ints.

    Works for 0-d scalars, per-shard ``(P,)`` stacks from ``run_sim`` and
    sharded outputs alike: every stat is either shard-uniform (schedules are
    pmax-reduced) or a quantity whose shard-max is the meaningful summary.

    This is the pipeline's *single* blessed device->host exit (repro-lint's
    ``host-sync`` rule, DESIGN.md §9): the shard-maxes are launched async on
    device and the whole dict crosses in one ``device_get``, not one
    blocking ``int()`` per stat.
    """
    host = jax.device_get({k: jnp.max(v) for k, v in stats.items()})
    return {k: int(v) for k, v in host.items()}


def run_sim(fn, P_size: int, sharded_args: tuple, broadcast_args: tuple = (),
            axis: str = AXIS):
    """Execute SPMD `fn` on ONE device by vmapping over the leading P axis.

    ``sharded_args`` carry a leading axis of size ``P_size``; ``broadcast_args``
    are replicated. `fn(*sharded, *broadcast)` must only communicate via
    ``AxisComm`` (over ``axis``).
    """
    in_axes = tuple(0 for _ in sharded_args) + tuple(None for _ in broadcast_args)
    return jax.vmap(fn, in_axes=in_axes, axis_name=axis,
                    axis_size=P_size)(*sharded_args, *broadcast_args)


def run_sharded(fn, mesh, sharded_args: tuple, broadcast_args: tuple = (),
                axis: str | None = None):
    """Execute SPMD `fn` over a real mesh shard axis via shard_map.

    ``axis`` defaults to ``shard_axis_of(mesh)`` — the coloring core never
    assumes the axis is literally named ``workers``.
    """
    axis = shard_axis_of(mesh) if axis is None else axis

    def wrapped(*args):
        ns = len(sharded_args)
        sh = [jax.tree.map(lambda x: x[0], a) for a in args[:ns]]
        out = fn(*sh, *args[ns:])
        return jax.tree.map(lambda x: x[None], out)

    in_specs = tuple(P(axis) for _ in sharded_args) + tuple(
        P() for _ in broadcast_args)
    return compat.shard_map(wrapped, mesh=mesh, in_specs=in_specs,
                            out_specs=P(axis), check=False)(
                                *sharded_args, *broadcast_args)


def run_sharded_many(fn, mesh, sharded_args: tuple, lane_args: tuple = (),
                     axis: str | None = None):
    """Execute a lane-vmapped SPMD ``fn`` on a 2D ``batch × shard`` mesh.

    ``fn`` is the per-shard program already vmapped over a leading graph-lane
    axis (``jax.vmap(color_then_recolor)``-style).  ``sharded_args`` carry
    ``(P, B, ...)``: dim 0 shards over the shard axis, dim 1 over the batch
    axis (so each device holds ``B / batch_size`` lanes of one shard) —
    the vmap graph axis and the shard_map graph axis are distinct mesh
    dimensions instead of vmap-inside-shard_map.  ``lane_args`` carry
    ``(B, ...)`` per-lane values (RNG keys): sharded over the batch axis
    only, replicated across shards.

    On a mesh without a ``batch`` axis this defers to ``run_sharded`` with
    the lanes as broadcast args — bitwise (and program-structure-wise) the
    1-axis ``color_many_sharded`` path, which is also what a 2D mesh with
    ``batch=1`` lowers to per shard.  ``B`` must divide by the batch-axis
    size (the pipeline driver pads lanes to a multiple).
    """
    axis = shard_axis_of(mesh) if axis is None else axis
    baxis = batch_axis_of(mesh)
    if baxis is None:
        return run_sharded(fn, mesh, sharded_args, lane_args, axis=axis)
    # 2D mesh (a batch=1 axis included — every device then holds all B
    # lanes, which is exactly the 1-axis per-shard program):

    def wrapped(*args):
        ns = len(sharded_args)
        sh = [jax.tree.map(lambda x: x[0], a) for a in args[:ns]]
        out = fn(*sh, *args[ns:])
        return jax.tree.map(lambda x: x[None], out)

    in_specs = tuple(P(axis, baxis) for _ in sharded_args) + tuple(
        P(baxis) for _ in lane_args)
    return compat.shard_map(wrapped, mesh=mesh, in_specs=in_specs,
                            out_specs=P(axis, baxis), check=False)(
                                *sharded_args, *lane_args)


def exchange_boundary(view: jnp.ndarray, boundary: jnp.ndarray,
                      ghost_owner: jnp.ndarray, ghost_slot: jnp.ndarray,
                      n_local_max: int, comm: AxisComm,
                      wire_dtype=None) -> jnp.ndarray:
    """One broadcast boundary-color exchange (all-gather scheme).

    Ships only boundary colors: payload (max_b,), all-gathered to (P, max_b);
    ghost slots refresh with one gather. ``wire_dtype=jnp.int16`` halves the
    ICI bytes (colors are bounded by max_colors <= 32767, config-asserted);
    see DESIGN.md §6.
    """
    payload = view[boundary]                      # (max_b,)
    if wire_dtype is not None:
        payload = payload.astype(wire_dtype)
    table = comm.all_gather(payload)              # (P, max_b)
    ghosts = table[ghost_owner, ghost_slot]       # (max_g,)
    return jax.lax.dynamic_update_slice(view, ghosts.astype(view.dtype),
                                        (n_local_max,))


def exchange_sparse(view: jnp.ndarray, send_slot: jnp.ndarray,
                    ghost_shift: jnp.ndarray, ghost_pos: jnp.ndarray,
                    shifts: tuple, widths: tuple, P_size: int,
                    n_local_max: int, comm: AxisComm, wire_dtype=None,
                    itemsize: int = 4, round_mask=None,
                    byte_widths=None, apply_mask=None) -> jnp.ndarray:
    """One sparse neighbour-to-neighbour exchange (``ppermute`` rounds).

    Round ``r`` ships, for every shard p at once, the ``widths[r]`` boundary
    colors that destination ``(p + shifts[r]) % P`` actually reads
    (``send_slot[r]``, sentinel-padded).  The receiver refreshes exactly the
    ghosts whose owner sits ``shifts[r]`` ring positions behind it
    (``ghost_shift == shifts[r]``) from position ``ghost_pos`` of the buffer.
    The schedule (shifts, widths) is static per graph — rounds with zero
    global traffic do not exist, so a graph with no cross edges exchanges
    zero bytes.

    ``round_mask`` (optional, (n_rounds,) bool, shard-uniform) lets callers
    skip rounds no destination currently needs (the sparse form of the
    paper's piggybacking, see recolor.py); skipped rounds cost no wire bytes.

    ``apply_mask`` (optional, (n_rounds,) bool) masks which executed rounds
    this caller actually *applies* (ghost refresh + byte accounting).  On a
    2D ``batch × shard`` mesh the executed schedule is the lane-uniform
    union over batch lanes — every device must run the same ``ppermute``
    sequence — while each lane keeps its own piggyback schedule here, so a
    lane never refreshes a ghost (or accounts a byte) ahead of its solo
    schedule.  ``None`` applies every executed round (the 1-axis/sim path,
    where ``round_mask`` already *is* the lane's own schedule).

    ``byte_widths`` (optional, (n_rounds,) int32, traced) overrides the
    *accounted* payload width per round without changing the shipped buffer
    shape.  The batched multi-graph pipeline runs every graph of a bucket on
    the union round schedule (``graph._union_comm_arrays``); a graph's own
    narrower (or absent) round still ships the union-width buffer — the
    extra entries are sentinel colors no receiver reads — but its measured
    ``wire_bytes`` stay those of its own plan, bitwise matching a solo run.
    Returns ``(view, wire_bytes)``.
    """
    n_ghost_slots = view.shape[0] - n_local_max - 1
    ghosts = jax.lax.dynamic_slice(view, (n_local_max,), (n_ghost_slots,))
    # contract: the round mask comes out of the pmax-reduced piggyback
    # schedule (recolor._needed_exchange_rounds), so every shard agrees on
    # which ppermute rounds run — a shard skipping a round its peer
    # executes would deadlock the exchange.
    round_mask = shard_uniform(round_mask)
    total = jnp.int32(0)
    for r, (k, w) in enumerate(zip(shifts, widths)):
        perm = [(i, (i + k) % P_size) for i in range(P_size)]
        mine = ghost_shift == k

        def do_round(args, perm=perm, r=r, w=w, mine=mine):
            ghosts, total = args
            payload = view[send_slot[r, :w]]
            if wire_dtype is not None:
                payload = payload.astype(wire_dtype)
            buf = comm.ppermute(payload, perm)
            vals = buf[jnp.minimum(ghost_pos, w - 1)].astype(ghosts.dtype)
            b = (jnp.int32(w * itemsize) if byte_widths is None
                 else byte_widths[r].astype(jnp.int32) * itemsize)
            keep = mine
            if apply_mask is not None:
                keep = mine & apply_mask[r]
                b = jnp.where(apply_mask[r], b, jnp.int32(0))
            return jnp.where(keep, vals, ghosts), total + b

        if round_mask is None:
            ghosts, total = do_round((ghosts, total))
        else:
            ghosts, total = jax.lax.cond(round_mask[r], do_round,
                                         lambda a: a, (ghosts, total))
    view = jax.lax.dynamic_update_slice(view, ghosts.astype(view.dtype),
                                        (n_local_max,))
    return view, total


def make_exchange(arrs, n_local_max: int, P_size: int, comm: AxisComm,
                  cfg: CommConfig, plan_static):
    """Build ``exchange(view[, round_mask]) -> (view, wire_bytes)``.

    ``plan_static`` is ``(shifts, widths)`` from ``PartitionedGraph.comm_plan``
    (hashable, part of the jit cache key).  Under the all-gather scheme the
    modeled wire bytes are ``(P-1) * max_b * itemsize`` per exchange — what a
    ring all-gather makes every shard receive; ``round_mask`` is ignored
    (the broadcast always ships everything).
    """
    if cfg.scheme == SPARSE:
        shifts, widths = plan_static
        # present only for bucketed (batched multi-graph) arrays: the
        # per-graph byte-accounting override on the shared round schedule
        byte_widths = arrs.get("round_widths")

        def exchange(view, round_mask=None, apply_mask=None):
            return exchange_sparse(
                view, arrs["send_slot"], arrs["ghost_shift"],
                arrs["ghost_pos"], shifts, widths, P_size, n_local_max,
                comm, wire_dtype=cfg.wire_dtype, itemsize=cfg.itemsize,
                round_mask=round_mask, byte_widths=byte_widths,
                apply_mask=apply_mask)

        return exchange

    max_b = arrs["boundary"].shape[0]
    if P_size is None:
        p_count = jax.lax.psum(jnp.int32(1), comm.axis)
        bytes_per_ex = (p_count - 1) * jnp.int32(max_b * cfg.itemsize)
    else:
        bytes_per_ex = jnp.int32(
            allgather_bytes_per_exchange(P_size, max_b, cfg.itemsize))

    def exchange(view, round_mask=None, apply_mask=None):
        view = exchange_boundary(
            view, arrs["boundary"], arrs["ghost_owner"], arrs["ghost_slot"],
            n_local_max, comm, wire_dtype=cfg.wire_dtype)
        return view, bytes_per_ex

    return exchange
