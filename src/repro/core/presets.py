"""The paper's two recommended parameter sets (§4.3, §5).

  "speed"   — FIxxND0: First Fit, Internal-First ordering, no recoloring.
  "quality" — R(5–10)IxxND1: Random-X Fit (X=5..10), Internal-First ordering,
              one (or more) ND recoloring iterations.
"""
from __future__ import annotations

import dataclasses

from . import ordering, selection
from .recolor import ND, RecolorConfig
from .speculative import ColorConfig


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    ordering: str
    color_cfg: ColorConfig
    recolor_iters: int
    recolor_perm: str = ND


def speed(max_colors: int = 1024, superstep: int = 512) -> Preset:
    return Preset(
        name="speed", ordering=ordering.INTERNAL_FIRST,
        color_cfg=ColorConfig(max_colors=max_colors, superstep=superstep,
                              selection=selection.FIRST_FIT),
        recolor_iters=0,
    )


def quality(x: int = 10, max_colors: int = 1024, superstep: int = 512,
            iters: int = 1) -> Preset:
    return Preset(
        name="quality", ordering=ordering.INTERNAL_FIRST,
        color_cfg=ColorConfig(max_colors=max_colors, superstep=superstep,
                              selection=selection.RANDOM_X, random_x=x),
        recolor_iters=iters,
    )


def run_preset(pg, preset: Preset, seed: int = 0):
    """Initial coloring + recoloring per the preset; returns (view, log)."""
    from . import ordering as ord_mod
    from .recolor import recolor_iterations
    from .speculative import color_graph_sim

    order = ord_mod.compute_order(pg, preset.ordering)
    cfg = dataclasses.replace(preset.color_cfg, seed=seed)
    view, stats = color_graph_sim(pg, order, cfg)
    log = [dict(stage="initial", **stats)]
    if preset.recolor_iters:
        rcfg = RecolorConfig(max_colors=cfg.max_colors, seed=seed)
        view, hist = recolor_iterations(pg, view, preset.recolor_iters, rcfg,
                                        base_perm=preset.recolor_perm,
                                        seed=seed)
        log += [dict(stage="recolor", **h) for h in hist]
    return view, log
