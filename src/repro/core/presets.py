"""The paper's two recommended parameter sets (§4.3, §5).

  "speed"   — FIxxND0: First Fit, Internal-First ordering, no recoloring.
  "quality" — R(5–10)IxxND1: Random-X Fit (X=5..10), Internal-First ordering,
              one (or more) ND recoloring iterations.
"""
from __future__ import annotations

import dataclasses

from . import ordering, selection
from .recolor import ND, RecolorConfig
from .speculative import ColorConfig


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    ordering: str
    color_cfg: ColorConfig
    recolor_iters: int
    recolor_perm: str = ND


def speed(max_colors: int = 1024, superstep: int = 512) -> Preset:
    return Preset(
        name="speed", ordering=ordering.INTERNAL_FIRST,
        color_cfg=ColorConfig(max_colors=max_colors, superstep=superstep,
                              selection=selection.FIRST_FIT),
        recolor_iters=0,
    )


def quality(x: int = 10, max_colors: int = 1024, superstep: int = 512,
            iters: int = 1) -> Preset:
    return Preset(
        name="quality", ordering=ordering.INTERNAL_FIRST,
        color_cfg=ColorConfig(max_colors=max_colors, superstep=superstep,
                              selection=selection.RANDOM_X, random_x=x),
        recolor_iters=iters,
    )


def pipeline_config(preset: Preset, *, n_iters: int | None = None,
                    patience: int = 0, seed: int = 0):
    """A preset as one fused-pipeline config (``pipeline_sim``-ready).

    ``n_iters`` overrides the preset's recoloring budget (``patience`` adds
    the adaptive stop on top); the RNG streams match ``run_preset``'s, so
    both entry points produce identical colorings for the same seed.
    """
    from .pipeline import PipelineConfig

    return PipelineConfig(
        color=dataclasses.replace(preset.color_cfg, seed=seed),
        recolor=RecolorConfig(max_colors=preset.color_cfg.max_colors,
                              seed=seed),
        n_iters=preset.recolor_iters if n_iters is None else n_iters,
        base_perm=preset.recolor_perm, patience=patience, seed=seed)


def run_preset(pg, preset: Preset, seed: int = 0):
    """Initial coloring + recoloring per the preset; returns (view, log).

    Runs device-resident through the fused pipeline when the preset
    recolors (one jitted program; bitwise the split dispatch it replaced);
    ``log`` is one dict per stage: ``stage="initial"`` with the coloring
    stats, then one ``stage="recolor"`` entry per executed iteration.
    """
    from . import ordering as ord_mod
    from .pipeline import pipeline_sim
    from .speculative import color_graph_sim

    order = ord_mod.compute_order(pg, preset.ordering)
    if not preset.recolor_iters:
        cfg = dataclasses.replace(preset.color_cfg, seed=seed)
        view, stats = color_graph_sim(pg, order, cfg)
        return view, [dict(stage="initial", **stats)]
    view, res = pipeline_sim(pg, order, pipeline_config(preset, seed=seed))
    log = [dict(stage="initial", **res["color"])]
    log += [dict(stage="recolor", **h) for h in res["history"]]
    return view, log
