"""Message accounting for the piggybacking study (paper §3.1, Fig. 4).

The paper counts MPI point-to-point messages between processor pairs during
one recoloring iteration:

  base scheme     — every processor sends one message per color step to every
                    neighbouring processor (including *empty* messages, which
                    the paper's Fig. 1 highlights).
  piggybacked     — processor P1 sends to P2 only at the last step before P2
                    first needs any pending color ("the color step before the
                    step where P2 needs any of the information contained in
                    the whole buffer"), plus one deferred end-of-iteration
                    message if anything remains.

On TPU the pairwise sends become boundary all-gathers, so the *runtime* win
is collective elision (see recolor.py); this module reproduces the paper's
message-count accounting analytically from the same schedule, per pair, so
Fig. 4's ≈80% message-reduction claim can be checked directly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graph import PartitionedGraph


@dataclasses.dataclass(frozen=True)
class MessageStats:
    n_pairs: int                 # ordered neighbouring (sender, receiver) pairs
    base_total: int              # base: one msg per pair per step
    base_nonempty: int           # base msgs that actually carry colors
    base_empty: int
    pig_total: int               # piggybacked msgs (incl. end-of-iteration)
    collective_steps_base: int   # all-gather count without coalescing (=K)
    collective_steps_pig: int    # all-gather count with coalescing

    @property
    def message_reduction(self) -> float:
        return 1.0 - self.pig_total / max(self.base_total, 1)

    @property
    def nonempty_reduction(self) -> float:
        return 1.0 - self.pig_total / max(self.base_nonempty, 1)

    @property
    def collective_reduction(self) -> float:
        return 1.0 - self.collective_steps_pig / max(self.collective_steps_base, 1)


def message_stats(pg: PartitionedGraph, colors: np.ndarray,
                  rank_of_color: np.ndarray) -> MessageStats:
    """Count base vs piggybacked messages for one RC iteration.

    `colors` is the seed coloring (n_global,), `rank_of_color[c]` the step of
    class c (1-based; rank_of_color[0] ignored).
    """
    K = int(rank_of_color.max(initial=0))
    step = rank_of_color[colors]                       # (n_global,) step per vtx
    owner = np.searchsorted(pg.offs, np.arange(pg.n_global), side="right") - 1

    # Collect all cross edges (u_owner != v_owner) once, as (pu, pv, su, sv).
    pairs_sender: dict[tuple[int, int], np.ndarray] = {}
    cross_su, cross_sv, cross_pu, cross_pv = [], [], [], []
    for p in range(pg.P):
        nl = int(pg.n_local[p])
        lo = int(pg.offs[p])
        indptr, indices = pg.indptr[p], pg.indices[p]
        m = indptr[nl]
        src = pg.edge_src[p, :m]
        dst = indices[:m]
        ghost = dst >= pg.n_local_max
        if not ghost.any():
            continue
        gidx = dst[ghost] - pg.n_local_max
        u_global = lo + src[ghost]                      # local writer/reader
        v_global = pg.gvid[p, pg.n_local_max + gidx]    # remote endpoint
        cross_pu.append(np.full(u_global.shape, p))
        cross_pv.append(owner[v_global])
        cross_su.append(step[u_global])
        cross_sv.append(step[v_global])
    if not cross_pu:
        return MessageStats(0, 0, 0, 0, 0, K, K)
    pu = np.concatenate(cross_pu)
    pv = np.concatenate(cross_pv)
    su = np.concatenate(cross_su)
    sv = np.concatenate(cross_sv)

    # --- base scheme: sender p1 -> receiver p2 at end of every step 1..K.
    pair_ids = np.unique(pu.astype(np.int64) * pg.P + pv)
    n_pairs = len(pair_ids)
    base_total = n_pairs * K
    # non-empty base msg at (p1->p2, step t): p1 colored a boundary vertex at
    # step t that p2 can see (i.e., edge (u in p1, v in p2) with step[u] = t).
    nonempty = np.unique((pu.astype(np.int64) * pg.P + pv) * (K + 1) + su)
    base_nonempty = len(nonempty)

    # --- piggybacked: for each (p1->p2), send at step min over pending deps.
    # p2 needs u's color (u in p1) before step sv (reader side), i.e. at step
    # sv-1, only when sv > su; later-read colors defer to iteration end.
    dep = sv > su
    pig_msgs = 0
    deferred_pairs = 0
    pair_key = pu.astype(np.int64) * pg.P + pv
    for pk in pair_ids:
        m = pair_key == pk
        send_steps = np.unique(sv[m & dep] - 1)        # just-in-time sends
        pig_msgs += len(send_steps)
        # anything with sv <= su is only needed next iteration -> one deferred
        # message at iteration end, unless it can piggyback on a later send.
        has_defer = (m & ~dep).any()
        last_assign = su[m].max(initial=0)
        if has_defer and (len(send_steps) == 0 or send_steps.max(initial=0)
                          < last_assign):
            deferred_pairs += 1
    pig_total = pig_msgs + deferred_pairs

    # --- collective view (what the TPU path executes): one all-gather per
    # needed step, OR-reduced over pairs, + the end-of-iteration gather.
    need_steps = np.unique(sv[dep] - 1)
    collective_pig = len(np.setdiff1d(need_steps, [K])) + 1
    return MessageStats(
        n_pairs=n_pairs, base_total=base_total, base_nonempty=base_nonempty,
        base_empty=base_total - base_nonempty, pig_total=pig_total,
        collective_steps_base=K, collective_steps_pig=collective_pig,
    )
