"""Validation and statistics helpers (host-side, numpy)."""
from __future__ import annotations

import numpy as np

from .graph import Graph, PartitionedGraph


def colors_from_views(pg: PartitionedGraph, views) -> np.ndarray:
    """(P, n_slots) device views -> (n_global,) color vector."""
    views = np.asarray(views)
    return pg.gather_global_colors(views[:, : pg.n_local_max])


def _d2_conflicting_pairs(g: Graph, colors: np.ndarray,
                          marked: np.ndarray) -> int:
    """Distinct marked vertex pairs with a common neighbour + equal color.

    Distance-2 properness == for every vertex w, the (marked, colored)
    neighbours of w carry pairwise-distinct colors; duplicates are found by
    sorting each CSR row's neighbour colors (one global lexsort).  The count
    dedups witness pairs, so it is exact for "zero conflicts" and a witness
    count (adjacent duplicates per row) otherwise.
    """
    src = np.repeat(np.arange(g.n), g.degrees)
    nbr = g.indices
    ok = marked[nbr] & (colors[nbr] > 0)
    w, c, v = src[ok], colors[nbr[ok]], nbr[ok]
    order = np.lexsort((v, c, w))
    w, c, v = w[order], c[order], v[order]
    dup = (w[1:] == w[:-1]) & (c[1:] == c[:-1])
    if not dup.any():
        return 0
    a = np.minimum(v[1:][dup], v[:-1][dup]).astype(np.int64)
    b = np.maximum(v[1:][dup], v[:-1][dup]).astype(np.int64)
    return int(np.unique(a * g.n + b).shape[0])


def check_coloring(g: Graph, colors: np.ndarray, *, distance: int = 1,
                   marked: np.ndarray | None = None) -> dict:
    """Validity + quality stats of a global coloring.

    ``colors`` — ``(g.n,)`` 1-based ints (0 = uncolored; from
    ``colors_from_views`` or ``color_many``'s ``"colors"``).  ``distance=2``
    additionally requires any two (marked) vertices with a common neighbour
    to differ in color.  ``marked`` — ``(g.n,)`` bool — restricts the
    checked vertex set (partial coloring): unmarked vertices may stay
    uncolored and never count as conflicts.  Sentinel colors (``<= 0``,
    e.g. a leaked ``-1``) must never crash the checker — they are reported
    as uncolored vertices with ``valid=False``.

    Returns a dict: ``valid``; ``n_conflicting_edges`` (undirected);
    ``n_uncolored``; ``n_colors`` — *distinct* colors in use, the paper's
    quality metric; ``max_color_id`` — the id bound (≥ ``n_colors`` on
    gappy colorings); ``class_sizes`` — ``(max_color_id,)`` counts indexed
    by color id - 1; ``class_balance`` — std/mean of the non-empty class
    sizes (0 = perfectly balanced); and at distance 2
    ``n_d2_conflicting_pairs``.
    """
    assert distance in (1, 2)
    colors = np.asarray(colors)
    if marked is None:
        marked = np.ones(g.n, dtype=bool)
    else:
        marked = np.asarray(marked, dtype=bool)
    src = np.repeat(np.arange(g.n), g.degrees)
    both = marked[src] & marked[g.indices]
    bad = both & (colors[src] > 0) & (colors[src] == colors[g.indices])
    n_uncolored = int((marked & (colors <= 0)).sum())
    cm = colors[marked]
    cm = cm[cm > 0]
    # Quality metric = number of *distinct* colors in use.  Recoloring (and
    # staggered selection) can empty classes below the maximum id, so the max
    # id alone overstates the paper's color count on gappy colorings; the id
    # bound stays available as ``max_color_id``.
    max_color_id = int(cm.max(initial=0))
    n_colors = int(np.unique(cm).size)
    counts = np.bincount(cm, minlength=max_color_id + 1)[1:]
    nonempty = counts[counts > 0]
    out = dict(
        valid=n_uncolored == 0 and not bad.any(),
        n_conflicting_edges=int(bad.sum()) // 2,
        n_uncolored=n_uncolored,
        n_colors=n_colors,
        max_color_id=max_color_id,
        class_sizes=counts,
        class_balance=float(nonempty.std() / max(nonempty.mean(), 1e-9))
        if n_colors else 0.0,
    )
    if distance == 2:
        n_d2 = _d2_conflicting_pairs(g, colors, marked)
        out["n_d2_conflicting_pairs"] = n_d2
        out["valid"] = out["valid"] and n_d2 == 0
    return out


def assert_valid(g: Graph, colors: np.ndarray, what: str = "coloring", *,
                 distance: int = 1, marked: np.ndarray | None = None):
    st = check_coloring(g, colors, distance=distance, marked=marked)
    assert st["valid"], (
        f"invalid {what}: {st['n_conflicting_edges']} conflicting edges, "
        f"{st.get('n_d2_conflicting_pairs', 0)} d2 pairs, "
        f"{st['n_uncolored']} uncolored, min color {colors.min(initial=0)}")
    return st
