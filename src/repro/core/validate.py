"""Validation and statistics helpers (host-side, numpy)."""
from __future__ import annotations

import numpy as np

from .graph import Graph, PartitionedGraph


def colors_from_views(pg: PartitionedGraph, views) -> np.ndarray:
    """(P, n_slots) device views -> (n_global,) color vector."""
    views = np.asarray(views)
    return pg.gather_global_colors(views[:, : pg.n_local_max])


def check_coloring(g: Graph, colors: np.ndarray) -> dict:
    """Validity + quality stats of a global coloring."""
    src = np.repeat(np.arange(g.n), g.degrees)
    bad = colors[src] == colors[g.indices]
    n_colors = int(colors.max(initial=0))
    counts = np.bincount(colors, minlength=n_colors + 1)[1:]
    return dict(
        valid=bool((colors > 0).all()) and not bad.any(),
        n_conflicting_edges=int(bad.sum()) // 2,
        n_colors=n_colors,
        class_sizes=counts,
        class_balance=float(counts.std() / max(counts.mean(), 1e-9))
        if n_colors else 0.0,
    )


def assert_valid(g: Graph, colors: np.ndarray, what: str = "coloring"):
    st = check_coloring(g, colors)
    assert st["valid"], (
        f"invalid {what}: {st['n_conflicting_edges']} conflicting edges, "
        f"min color {colors.min(initial=0)}")
    return st
