"""Version-compat shims over the jax API surface this repo uses.

The repo targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.set_mesh``) but must also run on older containers (0.4.x) where those
live under experimental names or do not exist:

  shard_map   jax.shard_map (new, ``check_vma``) vs
              jax.experimental.shard_map.shard_map (old, ``check_rep``)
  make_mesh   ``axis_types=`` keyword only exists once ``AxisType`` does
  set_mesh    ``jax.set_mesh(mesh)`` context manager vs ``with mesh:``

Every production entry point (core/comm.py, launch/*, train/trainer.py)
routes through these three helpers instead of touching the jax names
directly, so one shim covers the whole repo.
"""
from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    _shard_map = jax.shard_map
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
# the check_rep -> check_vma rename landed independently of the promotion
# out of jax.experimental, so detect the kwarg rather than assume
_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def shard_map(fn, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` with the replication check disabled portably.

    (``check_vma``/``check_rep`` =False: loop carries legitimately start
    replicated and become worker-varying after the first exchange.)
    """
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: check})


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # old jax: Mesh is itself a context manager
