"""Architecture configs + sharding plan.

Every assigned architecture is an ``ArchConfig``; the distribution strategy is
a ``ShardingPlan`` mapping *logical* axes to mesh axes:

  logical axis   meaning                          production mapping
  ------------   -------------------------------  -------------------------
  "batch"        activation batch dim (DP)        ("pod", "data")
  "fsdp"         weight d_model-ish dim (FSDP)    ("pod", "data")
  "tp"           weight hidden/head dim (TP)      ("model",)
  "exp"          MoE expert dim (EP)              ("model",)
  "seq"          KV/state sequence dim (SP)       ("data",)

Non-divisible dims fall back gracefully: axes are dropped right-to-left until
the dim divides (GSPMD could pad, but explicit fallback keeps the compiled
collectives predictable for the roofline analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------------------
# Sharding plan


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Logical-axis -> mesh-axes mapping (tuple entries = combined axes)."""

    batch: tuple[str, ...] = ()
    fsdp: tuple[str, ...] = ()
    tp: tuple[str, ...] = ()
    exp: tuple[str, ...] = ()
    seq: tuple[str, ...] = ()
    act_seq: tuple[str, ...] = ()  # Megatron-SP: residual S dim over "model"
    mesh_shape: dict[str, int] = dataclasses.field(default_factory=dict)

    def _axes(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return getattr(self, logical)

    def _size(self, axes: tuple[str, ...]) -> int:
        return int(np.prod([self.mesh_shape.get(a, 1) for a in axes],
                           initial=1))

    def spec(self, dims: tuple[str | None, ...],
             shape: tuple[int, ...] | None = None) -> P:
        """PartitionSpec for logical dims.

        Drops mesh axes that do not divide the dim (right-to-left) and never
        reuses a mesh axis across dims (first logical dim wins) — e.g. decode
        shapes shard batch over "data" and then leave the KV sequence dim
        replicated, while long-context (batch=1) shards the sequence instead.
        """
        entries: list[Any] = []
        used: set[str] = set()
        for i, d in enumerate(dims):
            axes = tuple(a for a in self._axes(d) if a not in used)
            if shape is not None:
                while axes and shape[i] % self._size(axes) != 0:
                    axes = axes[:-1]
            used.update(axes)
            if len(axes) == 0:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(axes[0])
            else:
                entries.append(tuple(axes))
        return P(*entries)


def plan_for_mesh(mesh) -> ShardingPlan:
    """Production plan from a mesh with axes ("pod",)? ("data", "model")."""
    names = tuple(mesh.axis_names)
    shape = dict(zip(names, mesh.devices.shape))
    dp = tuple(a for a in names if a in ("pod", "data"))
    tp = ("model",) if "model" in names else ()
    return ShardingPlan(batch=dp, fsdp=dp, tp=tp, exp=tp,
                        seq=("data",) if "data" in names else (),
                        act_seq=tp, mesh_shape=shape)


NO_SHARDING = ShardingPlan()


# --------------------------------------------------------------------------
# Architecture config


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact values from the assignment table)."""

    name: str
    family: str                   # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention flavour
    attn_kind: str = "gqa"        # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 1e4
    m_rope: bool = False          # qwen2-vl M-RoPE (3 position streams)
    # MLA dims (deepseek-v3 / minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # FFN flavour
    ffn_kind: str = "swiglu"      # swiglu | geglu | rwkv | mlp
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0             # expert hidden dim (d_ff used for dense FFN)
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_kind: str = ""            # rwkv6 | mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    attn_every: int = 0           # jamba: one attn layer per `attn_every`
    moe_every: int = 0            # jamba: MoE FFN every `moe_every` layers
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500
    # multimodal stub
    n_patches: int = 0            # qwen2-vl: patch embeddings prepended
    # numerics / training
    scale_embed: bool = False     # gemma: embed * sqrt(d_model)
    # Megatron-style SP for the residual stream: REFUTED under GSPMD on this
    # workload (52k AGs, 27x collective regression on deepseek — §Perf A.2);
    # kept as an opt-in knob for hand-placed-collective experiments.
    seq_parallel_acts: bool = False
    grad_accum: int = 1           # microbatches per step (activation memory)
    opt_state_dtype: str = "float32"  # bf16 halves optimizer HBM (deepseek)
    params_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    tie_embeddings: bool = False
    rms_eps: float = 1e-6
    # bookkeeping
    sub_quadratic: bool = False   # may run long_500k
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def vocab_padded(self, multiple: int = 256) -> int:
        return -(-self.vocab_size // multiple) * multiple

    def _flat_defs(self) -> dict[str, Any]:
        from repro.models.model import param_defs  # local import, no cycle

        flat: dict[str, Any] = {}

        def rec(prefix, node):
            if isinstance(node, dict):
                for k, v in node.items():
                    rec(f"{prefix}/{k}", v)
            else:
                flat[prefix] = node

        rec("", param_defs(self))
        return flat

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND."""
        return int(sum(np.prod(d.shape) for d in self._flat_defs().values()))

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        total = 0
        for name, d in self._flat_defs().items():
            sz = int(np.prod(d.shape))
            if "/experts/" in name:
                sz = sz * self.n_experts_per_tok // max(self.n_experts, 1)
            total += sz
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether the (arch, shape) cell runs; reason when skipped."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "O(S^2) full attention at 512k — skipped per assignment"
    return True, ""


_REGISTRY: dict[str, Any] = {}


def register(cfg_fn):
    _REGISTRY[cfg_fn.__name__.replace("_cfg", "")] = cfg_fn
    return cfg_fn


def get_arch(name: str, **overrides) -> ArchConfig:
    """Resolve an architecture by assignment id (e.g. 'qwen3-0.6b')."""
    from repro import configs  # noqa: F401  (triggers registration imports)
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[key]()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_archs() -> list[str]:
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)
