"""Architecture configs (one per assigned architecture) + sharding plans."""
from . import archs  # noqa: F401  — populates the registry
from .base import (SHAPES, ArchConfig, ShapeConfig, ShardingPlan, get_arch,
                   list_archs, plan_for_mesh, shape_applicable, NO_SHARDING)
from .archs import smoke_of

__all__ = ["SHAPES", "ArchConfig", "ShapeConfig", "ShardingPlan", "get_arch",
           "list_archs", "plan_for_mesh", "shape_applicable", "smoke_of",
           "NO_SHARDING"]
