"""The 10 assigned architectures — exact values from the assignment table.

Reduced smoke variants (same family, tiny dims) are derived by ``smoke_of``.
"""
from __future__ import annotations

import dataclasses

from .base import ArchConfig, register


@register
def moonshot_v1_16b_a3b() -> ArchConfig:
    # kimi/moonlight: 64 routed experts top-6 [hf:moonshotai/Moonlight-16B-A3B]
    return ArchConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=11264, vocab_size=163840,
        attn_kind="gqa", ffn_kind="swiglu", n_experts=64, n_experts_per_tok=6,
        n_shared_experts=2, moe_d_ff=1408, first_k_dense=1, rope_theta=5e4,
        grad_accum=4,
        notes="dense d_ff = 8*moe_d_ff for the first dense layer",
    )


@register
def deepseek_v3_671b() -> ArchConfig:
    # MLA + 1 shared + 256 routed top-8 [arXiv:2412.19437]
    return ArchConfig(
        name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
        n_heads=128, n_kv_heads=128, d_ff=18432, vocab_size=129280,
        attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
        qk_rope_dim=64, v_head_dim=128, ffn_kind="swiglu", n_experts=256,
        n_experts_per_tok=8, n_shared_experts=1, moe_d_ff=2048,
        first_k_dense=3, rope_theta=1e4, grad_accum=8,
        opt_state_dtype="bfloat16",
        notes="MTP head omitted (training objective addon; see DESIGN.md)",
    )


@register
def qwen3_0_6b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
        n_heads=16, n_kv_heads=8, d_ff=3072, vocab_size=151936, head_dim=128,
        attn_kind="gqa", qk_norm=True, ffn_kind="swiglu", rope_theta=1e6,
        tie_embeddings=True,
    )


@register
def gemma_2b() -> ArchConfig:
    # GeGLU, head_dim=256, MQA [arXiv:2403.08295]
    return ArchConfig(
        name="gemma-2b", family="dense", n_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, d_ff=16384, vocab_size=256000, head_dim=256,
        attn_kind="gqa", ffn_kind="geglu", rope_theta=1e4, scale_embed=True,
        tie_embeddings=True,
    )


@register
def qwen3_14b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=17408, vocab_size=151936,
        head_dim=128, attn_kind="gqa", qk_norm=True, ffn_kind="swiglu",
        rope_theta=1e6, grad_accum=4,
    )


@register
def minicpm3_4b() -> ArchConfig:
    # MLA [hf:openbmb/MiniCPM3-4B]
    return ArchConfig(
        name="minicpm3-4b", family="dense", n_layers=62, d_model=2560,
        n_heads=40, n_kv_heads=40, d_ff=6400, vocab_size=73448,
        attn_kind="mla", q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
        qk_rope_dim=32, v_head_dim=64, ffn_kind="swiglu", rope_theta=1e4,
        grad_accum=4,
    )


@register
def whisper_small() -> ArchConfig:
    # enc-dec; conv frontend stubbed: input_specs feeds frame embeddings
    return ArchConfig(
        name="whisper-small", family="audio", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=51865,
        attn_kind="gqa", ffn_kind="mlp", rope_theta=0.0, enc_dec=True,
        n_enc_layers=12, enc_len=1500,
        notes="sinusoidal positions (learned dec pos emb simplified away); "
              "MLP biases omitted",
    )


@register
def qwen2_vl_72b() -> ArchConfig:
    # M-RoPE, dynamic resolution (patch embeddings stubbed) [arXiv:2409.12191]
    return ArchConfig(
        name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=29568, vocab_size=152064,
        attn_kind="gqa", ffn_kind="swiglu", rope_theta=1e6, m_rope=True,
        n_patches=256, grad_accum=8, opt_state_dtype="bfloat16",
    )


@register
def rwkv6_1_6b() -> ArchConfig:
    # Finch — data-dependent decay [arXiv:2404.05892]
    return ArchConfig(
        name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
        n_heads=0, n_kv_heads=0, d_ff=7168, vocab_size=65536,
        attn_kind="none", ssm_kind="rwkv6", ffn_kind="rwkv",
        sub_quadratic=True,
    )


@register
def jamba_v0_1_52b() -> ArchConfig:
    # Mamba+attn 1:7 interleave, MoE 16e top-2 every other layer
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=65536,
        attn_kind="gqa", ffn_kind="swiglu", n_experts=16, n_experts_per_tok=2,
        moe_d_ff=14336, attn_every=8, moe_every=2, ssm_kind="mamba",
        d_state=16, d_conv=4, expand=2, rope_theta=1e4, sub_quadratic=True,
        grad_accum=8, opt_state_dtype="bfloat16",
    )


def smoke_of(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests."""
    over = dict(
        n_layers=min(cfg.n_layers, 4), d_model=128, d_ff=256,
        vocab_size=512, params_dtype="float32", compute_dtype="float32",
        enc_len=32, n_patches=8 if cfg.n_patches else 0,
        grad_accum=1, opt_state_dtype="float32",
    )
    if cfg.n_heads:
        over.update(n_heads=4, n_kv_heads=min(max(cfg.n_kv_heads, 1), 2),
                    head_dim=32)
    if cfg.attn_kind == "mla":
        over.update(q_lora_rank=(64 if cfg.q_lora_rank else 0),
                    kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                    v_head_dim=16)
    if cfg.is_moe:
        over.update(n_experts=8, n_experts_per_tok=2, moe_d_ff=64,
                    first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.family == "hybrid":
        over.update(n_layers=8, attn_every=4, moe_every=2)
    if cfg.enc_dec:
        over.update(n_enc_layers=2, n_layers=2)
    return dataclasses.replace(cfg, **over, name=cfg.name + "-smoke")
