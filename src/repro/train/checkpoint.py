"""Fault-tolerant checkpointing: atomic, checksummed, elastic-remesh restore.

Layout (one directory per step):

  ckpt_dir/step_000123/
    manifest.json      {step, keys, shapes, dtypes, crc32s, wallclock}
    <flatkey>.npy      one array per tree leaf (paths joined with '.')

Writes go to ``step_<n>.tmp`` then ``os.rename`` — a crash mid-save never
corrupts the latest valid checkpoint, and restore picks the newest manifest
whose checksums verify. ``restore(..., mesh=, defs=)`` re-shards every leaf
onto the *current* mesh (elastic scaling: save on 256 chips, resume on 512 —
tested on virtual meshes).

``save_async`` snapshots to host synchronously (cheap) and writes on a
background thread so the train loop overlaps I/O with compute.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path

import jax
import numpy as np

_SEP = "."


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}" if prefix or True
                                else k))
        return out
    out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def save(ckpt_dir, step: int, tree, *, keep: int = 3,
         extra: dict | None = None) -> Path:
    """Atomic synchronous checkpoint of a pytree-of-dicts."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = dict(step=step, wallclock=time.time(), extra=extra or {},
                    keys={}, format=1)
    for k, v in flat.items():
        np.save(tmp / f"{k}.npy", v)
        manifest["keys"][k] = dict(
            shape=list(v.shape), dtype=str(v.dtype),
            crc32=zlib.crc32(np.ascontiguousarray(v).tobytes()) & 0xFFFFFFFF)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir, step: int, tree, *, keep: int = 3,
               extra: dict | None = None) -> threading.Thread:
    """Snapshot to host now, write on a background thread."""
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    host_tree = _unflatten(flat)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs=dict(keep=keep, extra=extra), daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def _verify(path: Path) -> dict | None:
    try:
        manifest = json.loads((path / "manifest.json").read_text())
        for k, meta in manifest["keys"].items():
            v = np.load(path / f"{k}.npy")
            if zlib.crc32(np.ascontiguousarray(v).tobytes()) & 0xFFFFFFFF \
                    != meta["crc32"]:
                return None
        return manifest
    except Exception:
        return None


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in reversed(steps):
        if _verify(p) is not None:
            return int(p.name.split("_")[1])
    return None


def restore(ckpt_dir, step: int | None = None, *, mesh=None, specs=None):
    """Load the newest verified checkpoint; optionally re-shard onto `mesh`.

    `specs`: optional pytree of PartitionSpec matching the saved tree — leaves
    are placed with NamedSharding(mesh, spec) (elastic remesh restore).
    Returns (step, tree) or (None, None).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    path = ckpt_dir / f"step_{step:08d}"
    manifest = _verify(path)
    if manifest is None:
        raise IOError(f"checkpoint {path} failed verification")
    flat = {k: np.load(path / f"{k}.npy") for k in manifest["keys"]}
    tree = _unflatten(flat)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        flat_specs = _flatten(specs)
        tree = _unflatten({
            k: jax.device_put(v, NamedSharding(mesh, flat_specs[k]))
            if k in flat_specs else jax.device_put(v)
            for k, v in flat.items()})
    return step, tree
