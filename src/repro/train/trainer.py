"""Training loop with checkpoint/restart fault tolerance.

The loop is crash-equivalent: state = (params, opt_state) is checkpointed
every ``ckpt_every`` steps (async), the data stream is a pure function of the
step index, and any step-time failure (injected or real) triggers restore of
the newest verified checkpoint and replay. ``FailureInjector`` simulates node
failures at chosen steps to test the path (tests/test_trainer.py).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro import compat
from repro.configs.base import ArchConfig, ShardingPlan
from repro.data.pipeline import DataConfig, DataLoader
from repro.models import param_defs
from repro.models.layers import ParamDef, init_params
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, init_opt_state, opt_state_defs

IS_DEF = lambda t: isinstance(t, ParamDef)  # noqa: E731


class FailureInjector:
    """Raises once at each configured step — a stand-in for a node loss."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.pending = set(fail_at)
        self.fired: list[int] = []

    def maybe_fail(self, step: int):
        if step in self.pending:
            self.pending.discard(step)
            self.fired.append(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    async_ckpt: bool = True


class Trainer:
    def __init__(self, arch: ArchConfig, mesh, plan: ShardingPlan,
                 data_cfg: DataConfig, opt_cfg: OptConfig | None = None,
                 tcfg: TrainerConfig | None = None,
                 injector: FailureInjector | None = None):
        self.arch, self.mesh, self.plan = arch, mesh, plan
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg or OptConfig()
        self.tcfg = tcfg or TrainerConfig()
        self.injector = injector
        self.pdefs = param_defs(arch)
        self.param_specs = jax.tree.map(
            lambda d: plan.spec(d.dims, d.shape), self.pdefs, is_leaf=IS_DEF)
        odefs = opt_state_defs(self.pdefs, self.opt_cfg)
        self.opt_specs = jax.tree.map(
            lambda d: plan.spec(d.dims, d.shape), odefs, is_leaf=IS_DEF)
        # local import: launch.steps imports repro.train.optimizer, so a
        # module-level import here would be circular via repro.train.__init__
        from repro.launch.steps import make_train_step
        self._step_fn = jax.jit(
            make_train_step(arch, plan, self.opt_cfg),
            donate_argnums=(0, 1))
        self.history: list[dict] = []
        self.restarts = 0

    # -- state ------------------------------------------------------------
    def init_state(self):
        with compat.set_mesh(self.mesh):
            params = init_params_sharded(self.pdefs, self.mesh,
                                         self.param_specs, self.tcfg.seed)
            opt_state = init_opt_state(params, self.opt_cfg)
        return params, opt_state

    def save(self, step, params, opt_state):
        tree = {"params": params, "opt": opt_state}
        if self.tcfg.async_ckpt:
            self._ckpt_thread = ckpt.save_async(
                self.tcfg.ckpt_dir, step, tree, keep=self.tcfg.keep)
        else:
            ckpt.save(self.tcfg.ckpt_dir, step, tree, keep=self.tcfg.keep)

    def restore(self):
        specs = {"params": self.param_specs, "opt": self.opt_specs}
        step, tree = ckpt.restore(self.tcfg.ckpt_dir, mesh=self.mesh,
                                  specs=specs)
        if step is None:
            return 0, *self.init_state()
        return step, tree["params"], tree["opt"]

    # -- loop ---------------------------------------------------------------
    def run(self, num_steps: int | None = None):
        num_steps = num_steps or self.tcfg.num_steps
        step, params, opt_state = self.restore()
        loader = DataLoader(self.data_cfg, self.mesh, self.plan, self.arch,
                            start_step=step)
        t0 = time.time()
        while step < num_steps:
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                batch = next(loader)
                with compat.set_mesh(self.mesh):
                    params, opt_state, metrics = self._step_fn(
                        params, opt_state, batch)
                step += 1
                if step % self.tcfg.log_every == 0 or step == num_steps:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=step, wall=round(time.time() - t0, 2))
                    self.history.append(m)
                if step % self.tcfg.ckpt_every == 0 or step == num_steps:
                    self.save(step, params, opt_state)
            except RuntimeError as e:
                if "injected node failure" not in str(e):
                    raise
                # node loss: restore newest verified ckpt, replay stream
                self.restarts += 1
                step, params, opt_state = self.restore()
                loader = DataLoader(self.data_cfg, self.mesh, self.plan,
                                    self.arch, start_step=step)
        if getattr(self, "_ckpt_thread", None) is not None:
            self._ckpt_thread.join()
        return params, opt_state


def init_params_sharded(pdefs, mesh, specs, seed: int):
    """Initialize parameters directly with their target shardings."""
    from jax.sharding import NamedSharding
    flat_defs: dict[str, ParamDef] = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}", v)
        else:
            flat_defs[prefix] = node

    rec("", pdefs)
    flat_specs: dict = {}
    rec2 = lambda prefix, node: (  # noqa: E731
        [rec2(f"{prefix}/{k}", v) for k, v in node.items()]
        if isinstance(node, dict) else flat_specs.__setitem__(prefix, node))
    rec2("", specs)

    out: dict = {}
    key = jax.random.key(seed)
    for i, (name, d) in enumerate(sorted(flat_defs.items())):
        k = jax.random.fold_in(key, i)
        arr = d.initializer(k)
        arr = jax.device_put(arr, NamedSharding(mesh, flat_specs[name]))
        node = out
        parts = name.strip("/").split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out
