"""Training substrate: optimizer, trainer, checkpointing, compression."""
from . import checkpoint, compression, optimizer, trainer
from .optimizer import OptConfig, adamw_update, init_opt_state
from .trainer import FailureInjector, Trainer, TrainerConfig

__all__ = ["FailureInjector", "OptConfig", "Trainer", "TrainerConfig",
           "adamw_update", "checkpoint", "compression", "init_opt_state",
           "optimizer", "trainer"]
