"""Gradient compression: int8 error-feedback all-reduce (explicit-DP path).

Under jit+GSPMD the DP gradient all-reduce is implicit; to compress it the
reduction must be explicit. ``compressed_psum_tree`` runs inside a
``shard_map`` over the DP axis: each shard quantizes its local gradient to
int8 with a per-tensor scale, all-reduces the int8 payload (4× fewer bytes on
the wire), dequantizes, and keeps the quantization residual locally as error
feedback added to the next step's gradient — the EF-SGD/1-bit-Adam recipe
that preserves convergence.

``make_compressed_train_step`` wires it into a data-parallel train step
(per-shard grads → compressed AR → optimizer), used by tests and available
as a Trainer option for bandwidth-bound meshes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, err, axis: str):
    """int8 EF all-reduce of one tensor over `axis` (inside shard_map).

    Returns (mean-reduced tensor, new local error residual).
    """
    g = x.astype(jnp.float32) + err
    q, scale = quantize_int8(g)
    new_err = g - dequantize_int8(q, scale)
    # wire payload: int8 tensor + f32 scalar (scales summed alongside — each
    # shard's contribution is reconstructed as q_i * scale_i; summing
    # dequantized values is exact when done per-shard, so we all-reduce the
    # dequantized-but-int8-rounded values in f32-of-int8 form:
    total = jax.lax.psum(q.astype(jnp.float32) * scale, axis)
    n = jax.lax.psum(jnp.float32(1.0), axis)
    return total / n, new_err


def compressed_psum_tree(grads, errs, axis: str):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        r, ne = compressed_psum(g, e, axis)
        out_g.append(r.astype(g.dtype))
        out_e.append(ne)
    return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)


def wire_bytes(tree) -> tuple[int, int]:
    """(uncompressed f32 AR bytes, int8 EF-AR bytes) for a gradient tree."""
    leaves = jax.tree.leaves(tree)
    n = sum(int(x.size) for x in leaves)
    return 4 * n, n + 4 * len(leaves)


def make_compressed_train_step(loss_fn, opt_update, axis: str = "data"):
    """Explicit-DP train step with int8 EF gradient all-reduce.

    loss_fn(params, batch) -> (loss, aux); opt_update(params, grads, state)
    -> (params, state, info). Run under shard_map(..., axis_names=(axis,)).
    """
    def step(params, opt_state, err, batch):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, err = compressed_psum_tree(grads, err, axis)
        params, opt_state, info = opt_update(params, grads, opt_state)
        loss = jax.lax.pmean(loss, axis)
        return params, opt_state, err, {"loss": loss, **info}
    return step
