"""AdamW from scratch (no optax): pytree states, dtype policy, global clip.

Optimizer state mirrors the parameter sharding (FSDP over ("pod","data") ×
TP over "model"), so m/v never exceed the per-device parameter footprint.
``state_dtype="bfloat16"`` halves it again — the policy that lets
deepseek-v3-671B train on 512×16 GB (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"


def lr_at(step, cfg: OptConfig):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def opt_state_defs(pdefs, cfg: OptConfig) -> dict:
    """ParamDef table for the optimizer state (for dry-run SDS trees)."""
    def mv(d: ParamDef) -> ParamDef:
        return ParamDef(d.shape, d.dims, init="zeros", dtype=cfg.state_dtype)
    is_def = lambda t: isinstance(t, ParamDef)  # noqa: E731
    return {
        "m": jax.tree.map(mv, pdefs, is_leaf=is_def),
        "v": jax.tree.map(mv, pdefs, is_leaf=is_def),
        "count": ParamDef((), (), init="zeros", dtype="int32"),
    }


def init_opt_state(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.int32(0)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, opt_state, cfg: OptConfig):
    """One AdamW step; returns (params, opt_state, info)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(count, cfg)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        step_ = lr * (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + lr * cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - step_).astype(p.dtype),
                m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
