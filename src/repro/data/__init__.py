"""Data pipeline + coloring-based conflict-free scheduling."""
from . import coloring_sched, pipeline
from .pipeline import DataConfig, DataLoader, device_batch, host_batch

__all__ = ["DataConfig", "DataLoader", "coloring_sched", "device_batch",
           "host_batch", "pipeline"]
