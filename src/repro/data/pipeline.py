"""Deterministic synthetic data pipeline (step-indexed, shard-aware).

Batches are a pure function of (seed, step), so a restarted trainer replays
the exact stream — the property fault-tolerant training needs (no data-loader
state in the checkpoint). The generator is an affine bigram process with
noise, x_{t+1} = (a·x_t + b) mod V except ε-noise — a pattern a causal LM
provably can learn, so smoke-scale training shows a decreasing loss.

``host_batch`` returns numpy; ``device_batch`` places it with the plan's
batch sharding (scale-out: each data shard reads only its slice).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShardingPlan


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    noise: float = 0.05
    mult: int = 31
    add: int = 17


def host_batch(cfg: DataConfig, step: int, arch: ArchConfig | None = None):
    """Pure (seed, step) -> batch of numpy arrays (tokens, labels, stubs)."""
    rng = np.random.default_rng(np.random.PCG64DXSM(
        [cfg.seed, step]))
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    x = rng.integers(0, V, B).astype(np.int64)
    seq = np.empty((B, S + 1), np.int64)
    for t in range(S + 1):  # affine orbit x_{t+1} = (a·x_t + b) mod V
        seq[:, t] = x
        x = (cfg.mult * x + cfg.add) % V
    noise_mask = rng.random((B, S + 1)) < cfg.noise
    seq = np.where(noise_mask, rng.integers(0, V, (B, S + 1)), seq)
    batch = {"tokens": seq[:, :S].astype(np.int32),
             "labels": seq[:, 1:].astype(np.int32)}
    if arch is not None and arch.enc_dec:
        batch["enc_embeds"] = rng.normal(
            0, 1, (B, arch.enc_len, arch.d_model)).astype(np.float32)
    if arch is not None and arch.n_patches:
        batch["patch_embeds"] = rng.normal(
            0, 0.02, (B, arch.n_patches, arch.d_model)).astype(np.float32)
        batch["pos3"] = np.broadcast_to(np.arange(S, dtype=np.int32),
                                        (3, B, S)).copy()
    return batch


def device_batch(batch: dict, mesh, plan: ShardingPlan):
    """Place a host batch with the plan's batch sharding."""
    out = {}
    for k, v in batch.items():
        dims: tuple = ("batch",) + (None,) * (v.ndim - 1)
        if k == "pos3":
            dims = (None, "batch", None)
        out[k] = jax.device_put(
            v, NamedSharding(mesh, plan.spec(dims, v.shape)))
    return out


class DataLoader:
    """Step-indexed iterator with one-batch prefetch."""

    def __init__(self, cfg: DataConfig, mesh, plan: ShardingPlan,
                 arch: ArchConfig | None = None, start_step: int = 0):
        self.cfg, self.mesh, self.plan, self.arch = cfg, mesh, plan, arch
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self):
        b = device_batch(host_batch(self.cfg, self.step, self.arch),
                         self.mesh, self.plan)
        self.step += 1
        return b
