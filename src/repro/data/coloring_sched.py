"""Conflict-free scheduling via graph coloring — the paper's use case, live.

The paper's motivation (§1): "organizing computations so that no two
concurrent procedures access shared resources simultaneously". In a training
pipeline this appears when samples in a batch contend for the same mutable
resource — hot embedding rows updated sparsely, per-expert buffers, feature
hash buckets. Build the conflict graph (samples = vertices, shared resource =
edge), color it with the core library, and each color class becomes a
microbatch whose updates are write-conflict-free.

This module is exercised by examples/coloring_sched.py and tests.
"""
from __future__ import annotations

import numpy as np

from repro.core import (ColorConfig, color_graph_sim, colors_from_views,
                        compute_order, ordering, partition_graph, presets)
from repro.core.graph import Graph


def conflict_graph(resources: list[np.ndarray] | np.ndarray,
                   n_samples: int) -> Graph:
    """Samples sharing any resource id become adjacent.

    `resources`: (n_samples, r) int array (or list of variable-length arrays)
    of resource ids each sample touches.
    """
    if isinstance(resources, np.ndarray):
        resources = [resources[i] for i in range(resources.shape[0])]
    by_res: dict[int, list[int]] = {}
    for s, rs in enumerate(resources):
        for r in np.unique(rs):
            by_res.setdefault(int(r), []).append(s)
    src, dst = [], []
    for members in by_res.values():
        m = np.asarray(members)
        if len(m) < 2:
            continue
        # clique over samples sharing the resource
        i, j = np.triu_indices(len(m), k=1)
        src.append(m[i])
        dst.append(m[j])
    if not src:
        indptr = np.zeros(n_samples + 1, np.int64)
        return Graph(n_samples, indptr, np.zeros(0, np.int32))
    from repro.core.rmat import _edges_to_graph
    return _edges_to_graph(n_samples,
                           np.concatenate(src).astype(np.int32),
                           np.concatenate(dst).astype(np.int32))


def schedule(resources, n_samples: int, *, n_workers: int = 1,
             use_quality_preset: bool = True, seed: int = 0):
    """Color the conflict graph; return (groups, n_groups, stats).

    groups: list of np arrays of sample ids — each group is conflict-free and
    can be applied as one parallel microbatch.
    """
    g = conflict_graph(resources, n_samples)
    pg = partition_graph(g, n_workers, seed=seed)
    preset = presets.quality() if use_quality_preset else presets.speed()
    view, log = presets.run_preset(pg, preset, seed=seed)
    colors = colors_from_views(pg, np.asarray(view))
    n_groups = int(colors.max(initial=0))
    groups = [np.nonzero(colors == c)[0] for c in range(1, n_groups + 1)]
    return groups, n_groups, log


def schedule_many(batches, n_samples: int, *, n_workers: int = 1,
                  n_iters: int = 1, seed: int = 0):
    """Schedule MANY sample batches at once via the batched pipeline.

    ``batches`` is a sequence of per-batch resource arrays (each as in
    ``schedule``); every batch's conflict graph is partitioned and the
    whole set is dispatched through ``core.color_many`` — bucketed padding,
    one fused program per shape bucket (DESIGN.md §8), the serving shape of
    a training pipeline that colors a fresh conflict graph per step.
    Returns one ``(groups, n_groups, stats)`` triple per batch.
    """
    from repro.core import color_many

    pgs = [partition_graph(conflict_graph(res, n_samples), n_workers,
                           seed=seed) for res in batches]
    preset = presets.quality(iters=n_iters)
    cfg = presets.pipeline_config(preset, seed=seed)
    out = []
    for r in color_many(pgs, cfg, orders=preset.ordering, pad_batch=True):
        colors = r["colors"]
        n_groups = int(colors.max(initial=0))
        groups = [np.nonzero(colors == c)[0] for c in range(1, n_groups + 1)]
        out.append((groups, n_groups, dict(color=r["color"],
                                           history=r["history"],
                                           bucket=r["bucket"])))
    return out


def validate_schedule(resources, groups) -> bool:
    """No two samples in a group share a resource."""
    if isinstance(resources, np.ndarray):
        resources = [resources[i] for i in range(resources.shape[0])]
    for grp in groups:
        seen: set[int] = set()
        for s in grp:
            rs = set(int(r) for r in np.unique(resources[int(s)]))
            if seen & rs:
                return False
            seen |= rs
    return True
