"""Request-queue coloring service on the batched fused pipeline.

The paper's end-use is scheduling: color a conflict graph so each color
class runs concurrently.  In production that workload arrives as *many*
small-to-medium graphs (per-batch conflict graphs, per-tile Jacobian
sparsity patterns), not one giant one — so the serving shape is a queue:
accept graphs, bucket them by padded shape (``core.bucket_graphs``),
dispatch each bucket through ONE fused batched program
(``core.color_many`` / ``color_many_sharded``, DESIGN.md §8), and return
per-request colorings + stats.

``ColoringService`` is the embeddable driver (submit/flush); ``main`` runs
synthetic RMAT traffic and reports batched-vs-sequential dispatch
throughput — the pattern ``benchmarks/bench_serve.py`` measures rigorously.

CPU-scale:  PYTHONPATH=src python -m repro.launch.serve_coloring \
                --graphs 16 --p 4 --iters 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.core import (ColorConfig, Graph, PipelineConfig, RecolorConfig,
                        check_coloring, color_many, color_many_sharded,
                        ordering, partition_graph, rmat)


def default_config(*, max_colors: int = 1024, n_iters: int = 8,
                   distance: int = 1, patience: int = 2,
                   scheme: str | None = None) -> PipelineConfig:
    """The service's default pipeline: quality preset shape — Random-X seed
    coloring + ND recoloring with an adaptive stop.

    ``scheme=None`` follows ``$REPRO_SCHEME`` (sparse by default).  A
    long-running service at small P usually wants ``"allgather"``: the
    sparse scheme's static round plan is data-derived and lands in the jit
    cache key, so every fresh batch retraces, while the all-gather program
    depends on shapes only — with pow2 bucketing (``bucket_graphs``) and
    pow2 batch lanes it compiles once per bucket shape, ever."""
    kw = {} if scheme is None else dict(scheme=scheme)
    return PipelineConfig(
        color=ColorConfig(max_colors=max_colors, superstep=512,
                          selection="random_x", random_x=10,
                          distance=distance, **kw),
        recolor=RecolorConfig(max_colors=max_colors, distance=distance, **kw),
        n_iters=n_iters, base_perm="nd", patience=patience)


@dataclasses.dataclass
class _Job:
    id: int
    graph: Graph
    marked: np.ndarray | None


class ColoringService:
    """Queue graphs, color them in bucketed batches, return results by id.

    ``submit`` enqueues a ``core.Graph`` (plus an optional per-vertex
    ``marked`` mask when the config is partial) and returns a request id;
    ``flush`` partitions the queued graphs over ``P`` processors, buckets
    them, dispatches every bucket through the batched fused pipeline, and
    returns ``{request_id: result}`` where each result carries ``colors``
    ``(n,)`` 1-based, ``n_colors``, the per-iteration ``history``,
    ``n_iters_run`` and (``validate=True``) a ``check_coloring`` report.

    ``mesh=None`` uses the sim executor (P vmap lanes on one device); a
    mesh with a ``workers`` axis routes through ``color_many_sharded``.
    """

    def __init__(self, *, P: int = 4, cfg: PipelineConfig | None = None,
                 order_kind: str = ordering.INTERNAL_FIRST, mesh=None,
                 max_batch: int = 64, validate: bool = False, seed: int = 0):
        self.P = P
        self.cfg = cfg or default_config()
        self.order_kind = order_kind
        self.mesh = mesh
        self.max_batch = max_batch
        self.validate = validate
        self.seed = seed
        self._queue: list[_Job] = []
        self._next_id = 0

    @property
    def pending(self) -> int:
        return len(self._queue)

    def submit(self, g: Graph, *, marked: np.ndarray | None = None) -> int:
        """Enqueue one graph; returns the request id ``flush`` keys on."""
        assert self.cfg.color.partial == (marked is not None), (
            "marked= requires (and is required by) a partial color config")
        self._queue.append(_Job(self._next_id, g, marked))
        self._next_id += 1
        return self._queue[-1].id

    def _marked_blocks(self, pg, marked_g):
        """Global per-vertex mask -> the (P, n_local_max) block layout."""
        out = np.zeros((pg.P, pg.n_local_max), dtype=bool)
        for p in range(pg.P):
            nl, lo = int(pg.n_local[p]), int(pg.offs[p])
            out[p, :nl] = marked_g[lo:lo + nl]
        return out

    def flush(self) -> dict[int, dict]:
        """Dispatch the queue in batches of ``max_batch``; returns by id."""
        results: dict[int, dict] = {}
        halo = 2 if self.cfg.recolor.distance == 2 else 1
        while self._queue:
            jobs, self._queue = (self._queue[:self.max_batch],
                                 self._queue[self.max_batch:])
            pgs = [partition_graph(j.graph, self.P, seed=self.seed, halo=halo)
                   for j in jobs]
            marked = None
            if self.cfg.color.partial:
                marked = [self._marked_blocks(pg, j.marked)
                          for pg, j in zip(pgs, jobs)]
            run = (color_many if self.mesh is None
                   else lambda *a, **kw: color_many_sharded(
                       a[0], a[1], self.mesh, **kw))
            # pad_batch: pow2 batch lanes keep program shapes stable as the
            # queue depth fluctuates, so steady-state flushes stay compiled
            batch = run(pgs, self.cfg, orders=self.order_kind, marked=marked,
                        pad_batch=True)
            for j, r in zip(jobs, batch):
                out = dict(colors=r["colors"],
                           n_colors=(r["history"][-1]["n_colors_distinct"]
                                     if r["history"]
                                     else r["color"]["n_colors_distinct"]),
                           history=r["history"],
                           n_iters_run=r["n_iters_run"], bucket=r["bucket"])
                if self.validate:
                    out["check"] = check_coloring(
                        j.graph, r["colors"],
                        distance=self.cfg.recolor.distance, marked=j.marked)
                    assert out["check"]["valid"], (j.id, out["check"])
                results[j.id] = out
        return results


def _traffic(n_graphs: int, scale_lo: int, scale_hi: int, seed: int):
    """A synthetic request mix: the three RMAT classes at mixed scales."""
    rng = np.random.default_rng(seed)
    gens = (rmat.rmat_er, rmat.rmat_good, rmat.rmat_bad)
    return [gens[i % 3](int(rng.integers(scale_lo, scale_hi + 1)), 8,
                        seed=int(rng.integers(1 << 30)))
            for i in range(n_graphs)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=16)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--scale-min", type=int, default=6)
    ap.add_argument("--scale-max", type=int, default=8)
    ap.add_argument("--max-colors", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    graphs = _traffic(args.graphs, args.scale_min, args.scale_max, args.seed)
    svc = ColoringService(
        P=args.p, validate=True,
        cfg=default_config(max_colors=args.max_colors, n_iters=args.iters,
                           scheme="allgather"))   # shape-stable programs
    ids = [svc.submit(g) for g in graphs]

    t0 = time.time()
    res = svc.flush()                      # includes compile on first flush
    t_cold = time.time() - t0
    n_buckets = max(r["bucket"] for r in res.values()) + 1
    # steady state: FRESH graphs still hit the compiled bucket programs
    # (pow2 shapes + pow2 batch lanes + shape-only allgather exchange)
    for g in _traffic(args.graphs, args.scale_min, args.scale_max,
                      args.seed + 1):
        svc.submit(g)
    t0 = time.time()
    svc.flush()
    t_warm = time.time() - t0

    print(f"served {len(ids)} graphs over {n_buckets} buckets at "
          f"P={args.p}: cold {t_cold:.2f}s, warm {t_warm:.3f}s "
          f"({len(ids) / max(t_warm, 1e-9):.1f} graphs/s)")
    for i in ids[:8]:
        r = res[i]
        print(f"  req {i}: {r['n_colors']} colors after "
              f"{r['n_iters_run']} RC iters (bucket {r['bucket']}, "
              f"valid={r['check']['valid']})")


if __name__ == "__main__":
    main()
