"""Request-queue coloring service on the batched fused pipeline.

The paper's end-use is scheduling: color a conflict graph so each color
class runs concurrently.  In production that workload arrives as *many*
small-to-medium graphs (per-batch conflict graphs, per-tile Jacobian
sparsity patterns), not one giant one — so the serving shape is a queue:
accept graphs, bucket them by padded shape (``core.bucket_graphs``),
dispatch through the compiled-program cache (``core.pipeline``,
DESIGN.md §2/§8), and return per-request colorings + stats.

Routing is a per-request **cost model** (DESIGN.md §8): partitioning is
memoized by graph content, every request's padded-member pipeline
signature (``core.plan_signature``) probes the program cache, and

- a **hit** dispatches the request solo, immediately, through the
  *unbatched* fused program (``pipeline_sim``/``_sharded``) — no batch
  axis, no stacking, no batch wait: warm latency is one cached-program
  device dispatch;
- a **miss** routes to the batch lane, where requests needing the same
  new program share its one compile (and one dispatch).

``prewarm`` compiles the one-lane programs for expected traffic shapes up
front so steady-state requests take the hit path from the first flush.
Exchange schemes resolve per bucket at trace time (``scheme="auto"``):
the pow2-rung-quantized sparse plans are shape-stable, so the sparse
scheme's byte savings now ride the cached programs instead of forcing
the allgather fallback.

``ColoringService`` is the embeddable driver (submit/flush); ``main`` runs
synthetic RMAT traffic and reports batched-vs-sequential dispatch
throughput — the pattern ``benchmarks/bench_serve.py`` measures rigorously.

CPU-scale:  PYTHONPATH=src python -m repro.launch.serve_coloring \
                --graphs 16 --p 4 --iters 4
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import time
from collections import OrderedDict

import jax
import numpy as np

from repro.core import (ColorConfig, Graph, PipelineConfig, RecolorConfig,
                        bucket_graphs, bucket_signature, check_coloring,
                        color_many, color_many_sharded, compute_order,
                        ordering, partition_graph, pipeline_sharded,
                        pipeline_sim, plan_signature,
                        program_cache_contains, program_cache_stats, rmat)


def default_config(*, max_colors: int = 1024, n_iters: int = 8,
                   distance: int = 1, patience: int = 2,
                   scheme: str | None = None) -> PipelineConfig:
    """The service's default pipeline: quality preset shape — Random-X seed
    coloring + ND recoloring with an adaptive stop.

    ``scheme=None`` follows ``$REPRO_SCHEME`` (default ``"auto"``): each
    bucket picks sparse vs allgather at trace time from the modeled wire
    bytes, and the pow2-rung plan quantization keeps either choice
    compile-stable — there is no serving reason to force a scheme."""
    kw = {} if scheme is None else dict(scheme=scheme)
    return PipelineConfig(
        color=ColorConfig(max_colors=max_colors, superstep=512,
                          selection="random_x", random_x=10,
                          distance=distance, **kw),
        recolor=RecolorConfig(max_colors=max_colors, distance=distance, **kw),
        n_iters=n_iters, base_perm="nd", patience=patience)


def _graph_fingerprint(g: Graph) -> str:
    """Content hash of a graph — the partition-memo key."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(g.n).tobytes())
    h.update(np.ascontiguousarray(g.indptr).tobytes())
    h.update(np.ascontiguousarray(g.indices).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class _Job:
    id: int
    graph: Graph
    marked: np.ndarray | None


@dataclasses.dataclass
class _Entry:
    """Memoized per-unique-graph dispatch state (keyed by content hash)."""
    pg: object          # PartitionedGraph (original dims)
    bucket: object      # its one-graph GraphBucket (pow2-padded)
    signature: object   # the bucket's PlanSignature (batch-lane grouping)
    solo_sig: object    # the padded member's pipeline_sim/_sharded signature
    order: object       # visit order for the padded member (np array)
    exact_sig: object   # the original dims' pipeline signature (hot path)
    exact_order: object  # visit order for the original partition

    @property
    def member(self):
        """The pow2-padded partition the solo path dispatches."""
        return self.bucket.members[0]


class ColoringService:
    """Queue graphs, color them via the cost-model router, return by id.

    ``submit`` enqueues a ``core.Graph`` (plus an optional per-vertex
    ``marked`` mask when the config is partial) and returns a request id;
    ``flush`` routes every queued request — program-cache hit → immediate
    solo dispatch, miss → bucketed batch lane — and returns
    ``{request_id: result}`` where each result carries ``colors`` ``(n,)``
    1-based, ``n_colors``, the per-iteration ``history``,
    ``n_iters_run``, the dispatch ``route`` (``"solo"``/``"batch"``), its
    ``latency_s`` (wall time of the dispatch that produced it) and
    (``validate=True``) a ``check_coloring`` report.

    Request RNG keys fold the *request id* into the config seeds, so a
    request's coloring does not depend on which route or batch position
    served it.  ``mesh=None`` uses the sim executor (P vmap lanes on one
    device); a built mesh or a ``launch.mesh.MeshSpec`` (built here)
    routes through ``color_many_sharded`` over the mesh's shard axis
    (``core.shard_axis_of``) — a 2D ``MeshSpec.coloring(P, batch)`` mesh
    additionally shards the batch lane's graph axis over its ``batch``
    mesh axis.  ``stats()`` exposes the router counters and the
    process-wide program-cache counters.
    """

    def __init__(self, *, P: int = 4, cfg: PipelineConfig | None = None,
                 order_kind: str = ordering.INTERNAL_FIRST, mesh=None,
                 max_batch: int = 64, validate: bool = False, seed: int = 0,
                 memo_graphs: int = 256):
        self.P = P
        self.cfg = cfg or default_config()
        self.order_kind = order_kind
        if mesh is not None and hasattr(mesh, "build"):   # a MeshSpec
            mesh = mesh.build()
        self.mesh = mesh
        self.max_batch = max_batch
        self.validate = validate
        self.seed = seed
        self._queue: list[_Job] = []
        self._next_id = 0
        self._memo: OrderedDict[str, _Entry] = OrderedDict()
        self._memo_max = memo_graphs
        self._n_solo = self._n_batch = self._memo_hits = 0

    @property
    def pending(self) -> int:
        return len(self._queue)

    def submit(self, g: Graph, *, marked: np.ndarray | None = None) -> int:
        """Enqueue one graph; returns the request id ``flush`` keys on."""
        assert self.cfg.color.partial == (marked is not None), (
            "marked= requires (and is required by) a partial color config")
        self._queue.append(_Job(self._next_id, g, marked))
        self._next_id += 1
        return self._queue[-1].id

    def stats(self) -> dict:
        """Router + program-cache counters (cache stats are process-wide)."""
        return dict(solo=self._n_solo, batch=self._n_batch,
                    memo_hits=self._memo_hits, memo_size=len(self._memo),
                    signatures=len({e.signature
                                    for e in self._memo.values()}),
                    **program_cache_stats())

    def prewarm(self, samples) -> float:
        """Compile the one-lane programs for the given traffic samples.

        ``samples`` — representative ``core.Graph`` instances (e.g. one per
        expected shape bucket).  Each still-cold sample is dispatched once
        per missing solo program — the pow2-padded member's (shared by
        every later same-signature request) and the sample's exact-dims
        one (the cheapest dispatch for repeat-content traffic) — so
        steady-state requests take the hit path from their first flush.
        Returns the wall seconds spent; already-warm samples cost cache
        probes only.
        """
        t0 = time.perf_counter()
        for g in samples:
            e = self._entry(g)
            marked = (np.zeros(g.n, dtype=bool)
                      if self.cfg.color.partial else None)
            if not program_cache_contains(e.solo_sig):
                self._run_solo(_Job(0, g, marked), e, e.member, e.order)
            if not program_cache_contains(e.exact_sig):
                self._run_solo(_Job(0, g, marked), e, e.pg, e.exact_order)
        return time.perf_counter() - t0

    # ------------------------------------------------------------ internals --

    @property
    def _halo(self) -> int:
        return 2 if self.cfg.recolor.distance == 2 else 1

    def _entry(self, g: Graph) -> _Entry:
        """Partition + bucket + signature, memoized by graph content."""
        fp = _graph_fingerprint(g)
        e = self._memo.get(fp)
        if e is not None:
            self._memo.move_to_end(fp)
            self._memo_hits += 1
            return e
        pg = partition_graph(g, self.P, seed=self.seed, halo=self._halo)
        bucket = bucket_graphs([pg])[0]
        sig = bucket_signature(bucket, self.cfg, mesh=self.mesh)
        member = bucket.members[0]
        e = _Entry(pg=pg, bucket=bucket, signature=sig,
                   solo_sig=plan_signature(member, self.cfg, mesh=self.mesh),
                   order=compute_order(member, self.order_kind),
                   exact_sig=plan_signature(pg, self.cfg, mesh=self.mesh),
                   exact_order=compute_order(pg, self.order_kind))
        self._memo[fp] = e
        while len(self._memo) > self._memo_max:
            self._memo.popitem(last=False)
        return e

    def _marked_blocks(self, pg, marked_g):
        """Global per-vertex mask -> the (P, n_local_max) block layout."""
        out = np.zeros((pg.P, pg.n_local_max), dtype=bool)
        for p in range(pg.P):
            nl, lo = int(pg.n_local[p]), int(pg.offs[p])
            out[p, :nl] = marked_g[lo:lo + nl]
        return out

    def _keys(self, jobs):
        """Request-id-folded per-graph keys: route-independent results."""
        ck = jax.random.key(self.cfg.color.seed)
        rk = jax.random.key(self.cfg.seed)
        return ([jax.random.fold_in(ck, j.id) for j in jobs],
                [jax.random.fold_in(rk, j.id) for j in jobs])

    def _solo_dispatch(self, job, e: _Entry) -> dict:
        """One request through the *unbatched* fused program — the hit path.

        No batch axis, no stacking, no unpacking: warm same-program latency
        is one cached-program device dispatch (bitwise equal to the batch
        lane — padding is inert and the request-id-folded keys are route-
        independent).  Prefers the original-dims program (no padding
        compute; ``prewarm`` compiles it for sample graphs) and falls back
        to the pow2-padded member's, which fresh same-signature graphs
        share."""
        if program_cache_contains(e.exact_sig):
            tgt, order = e.pg, e.exact_order
        else:
            tgt, order = e.member, e.order
        return self._run_solo(job, e, tgt, order)

    def _run_solo(self, job, e: _Entry, tgt, order) -> dict:
        cks, rks = self._keys([job])
        marked = (self._marked_blocks(tgt, job.marked)
                  if self.cfg.color.partial else None)
        run = (pipeline_sim if self.mesh is None else
               lambda *a, **kw: pipeline_sharded(a[0], a[1], a[2], self.mesh,
                                                 **kw))
        view, res = run(tgt, order, self.cfg, marked=marked,
                        color_key=cks[0], recolor_key=rks[0])
        view = np.asarray(view)
        return dict(
            colors=e.pg.gather_global_colors(view[:, :e.pg.n_local_max]),
            color=res["color"], history=res["history"],
            n_iters_run=res["n_iters_run"], bucket=0)

    def _dispatch(self, jobs, entries=None, buckets=None):
        """One ``color_many`` call for ``jobs`` (solo entry or cold group)."""
        pgs = [e.pg for e in entries] if entries is not None else [
            partition_graph(j.graph, self.P, seed=self.seed, halo=self._halo)
            for j in jobs]
        if entries is not None and buckets is None:
            # reuse the memoized bucket object whenever its indices already
            # line up (always true for solo dispatch) — its union plan and
            # stacked arrays are cached on the instance, so a warm request
            # pays no host-side re-stack
            buckets = [e.bucket if e.bucket.indices == (i,) else
                       dataclasses.replace(e.bucket, indices=(i,))
                       for i, e in enumerate(entries)]
        marked = None
        if self.cfg.color.partial:
            marked = [self._marked_blocks(pg, j.marked)
                      for pg, j in zip(pgs, jobs)]
        cks, rks = self._keys(jobs)
        run = (color_many if self.mesh is None
               else lambda *a, **kw: color_many_sharded(
                   a[0], a[1], self.mesh, **kw))
        # pad_batch: pow2 batch lanes keep program shapes stable as the
        # queue depth fluctuates, so steady-state flushes stay compiled
        return run(pgs, self.cfg, orders=self.order_kind, marked=marked,
                   color_keys=cks, recolor_keys=rks, buckets=buckets,
                   pad_batch=True)

    def _finish(self, job, r, latency, route, results):
        out = dict(colors=r["colors"],
                   n_colors=(r["history"][-1]["n_colors_distinct"]
                             if r["history"]
                             else r["color"]["n_colors_distinct"]),
                   history=r["history"], n_iters_run=r["n_iters_run"],
                   bucket=r["bucket"], route=route, latency_s=latency)
        if self.validate:
            out["check"] = check_coloring(
                job.graph, r["colors"],
                distance=self.cfg.recolor.distance, marked=job.marked)
            assert out["check"]["valid"], (job.id, out["check"])
        results[job.id] = out

    def flush(self) -> dict[int, dict]:
        """Route and dispatch the queue in waves of ``max_batch``."""
        results: dict[int, dict] = {}
        while self._queue:
            jobs, self._queue = (self._queue[:self.max_batch],
                                 self._queue[self.max_batch:])
            pairs = [(j, self._entry(j.graph)) for j in jobs]

            def _warm(e):
                return (program_cache_contains(e.solo_sig)
                        or program_cache_contains(e.exact_sig))

            warm = [(j, e) for j, e in pairs if _warm(e)]
            cold = [(j, e) for j, e in pairs if not _warm(e)]
            # hit path: the program is compiled — serve each request now,
            # individually (latency = one device dispatch, no batch wait)
            for j, e in warm:
                t0 = time.perf_counter()
                out = self._solo_dispatch(j, e)
                self._finish(j, out, time.perf_counter() - t0, "solo",
                             results)
                self._n_solo += 1
            # miss path: group the new shapes so each fresh program
            # compiles (and dispatches) once for its whole sub-batch.
            # Grouping by *solo signature* (not raw dims) makes the group's
            # padded dims and union plan equal every member's own — pow2 of
            # a max is the max of pow2s — so the same traffic shape produces
            # the same batch program on every future flush.
            groups: OrderedDict = OrderedDict()
            for j, e in cold:
                groups.setdefault(e.signature, []).append((j, e))
            for sub in groups.values():
                bucket = bucket_graphs([e.pg for _, e in sub])[0]
                t0 = time.perf_counter()
                outs = self._dispatch([j for j, _ in sub],
                                      [e for _, e in sub], [bucket])
                lat = time.perf_counter() - t0
                for (j, _), r in zip(sub, outs):
                    self._finish(j, r, lat, "batch", results)
                    self._n_batch += 1
        return results


def _traffic(n_graphs: int, scale_lo: int, scale_hi: int, seed: int):
    """A synthetic request mix: the three RMAT classes at mixed scales."""
    rng = np.random.default_rng(seed)
    gens = (rmat.rmat_er, rmat.rmat_good, rmat.rmat_bad)
    return [gens[i % 3](int(rng.integers(scale_lo, scale_hi + 1)), 8,
                        seed=int(rng.integers(1 << 30)))
            for i in range(n_graphs)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=16)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--scale-min", type=int, default=6)
    ap.add_argument("--scale-max", type=int, default=8)
    ap.add_argument("--max-colors", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    graphs = _traffic(args.graphs, args.scale_min, args.scale_max, args.seed)
    svc = ColoringService(
        P=args.p, validate=True,
        cfg=default_config(max_colors=args.max_colors, n_iters=args.iters))
    ids = [svc.submit(g) for g in graphs]

    t0 = time.time()
    res = svc.flush()                      # includes compile on first flush
    t_cold = time.time() - t0
    n_buckets = max(r["bucket"] for r in res.values()) + 1
    # compile the one-lane programs for the shapes just seen, so
    # steady-state requests take the solo hit path from their first flush
    t_pre = svc.prewarm(graphs)
    # steady state: FRESH graphs still hit the compiled programs
    # (pow2 plan rungs + pow2 shapes + pow2 batch lanes)
    for g in _traffic(args.graphs, args.scale_min, args.scale_max,
                      args.seed + 1):
        svc.submit(g)
    t0 = time.time()
    res2 = svc.flush()
    t_warm = time.time() - t0
    lats = sorted(r["latency_s"] for r in res2.values())
    st = svc.stats()
    hit_rate = st["hits"] / max(st["hits"] + st["misses"], 1)

    print(f"served {len(ids)} graphs over {n_buckets} buckets at "
          f"P={args.p}: cold {t_cold:.2f}s, prewarm {t_pre:.2f}s, "
          f"warm {t_warm:.3f}s "
          f"({len(ids) / max(t_warm, 1e-9):.1f} graphs/s)")
    print(f"routes solo={st['solo']} batch={st['batch']} "
          f"program-cache hit rate {hit_rate:.2f} "
          f"p50 {lats[len(lats) // 2] * 1e3:.1f}ms "
          f"p99 {lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e3:.1f}ms")
    for i in ids[:8]:
        r = res[i]
        print(f"  req {i}: {r['n_colors']} colors after "
              f"{r['n_iters_run']} RC iters (bucket {r['bucket']}, "
              f"valid={r['check']['valid']})")


if __name__ == "__main__":
    main()
