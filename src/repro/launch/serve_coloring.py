"""Continuous-batching coloring service on the fused pipeline.

The paper's end-use is scheduling: color a conflict graph so each color
class runs concurrently.  In production that workload arrives as *many*
small-to-medium graphs (per-batch conflict graphs, per-tile Jacobian
sparsity patterns), not one giant one — so the serving shape is a queue
of heterogeneous requests competing for device time, and per-graph
latency is the currency.

Two scheduling modes (``ServeConfig.mode``, DESIGN.md §11):

- ``"continuous"`` (default) — an LLM-style continuous-batching
  scheduler.  Long-lived per-shape **engines** hold B lanes of one
  compiled ``(init, step)`` program pair; a freed lane admits the next
  compatible request mid-flight by swapping the new graph's arrays and a
  fresh request-folded key into the lane buffers (``core.pad_partition``
  slot remapping + ``core.remap_plan_arrays`` onto the engine's static
  exchange schedule — no recompile), while the other lanes keep stepping.
  ``submit`` returns a request id whose ``JobFuture`` resolves
  asynchronously; **admission control** under a latency SLO decides
  solo-dispatch (program-cache hit) vs lane admission vs shed/defer per
  request.  Every lane is bitwise-equal to a solo ``pipeline_sim`` run of
  the same engine-padded member under arbitrary admission interleavings
  (the chunked step applies the while loop's self-freezing body, see
  ``core.pipeline_step_spmd``).
- ``"flush"`` — the PR 6 batch-synchronous cost-model router: cache-probe
  hit → immediate solo dispatch, miss → grouped batch compile
  (``core.color_many``).  A straggler graph holds its whole bucket
  hostage until the batch program returns — the p99 cliff the continuous
  mode exists to remove (``benchmarks/bench_serve.py`` measures both
  against open-loop Poisson arrivals).

Request RNG keys fold the *request id* into the config seeds, so a
request's coloring does not depend on which route, lane or batch position
served it.  Time is read through an injectable ``Clock`` (default
``WallClock``); tests drive the scheduler on a ``FakeClock`` with
scripted arrivals (``tests/serve_harness.py``) — zero sleeps, zero
flakes.

CPU-scale:  PYTHONPATH=src python -m repro.launch.serve_coloring \
                --graphs 16 --p 4 --iters 4
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ColorConfig, Graph, PipelineConfig, RecolorConfig,
                        bucket_graphs, bucket_signature, check_coloring,
                        color_many, color_many_sharded, compute_order,
                        engine_init_program, engine_put_program,
                        engine_step_program, ordering,
                        pad_partition, partition_graph, pipeline_sharded,
                        pipeline_sim, plan_fits, plan_signature,
                        program_cache_contains, program_cache_stats,
                        remap_plan_arrays, resolve_pipeline_cfg, rmat)
from repro.core.pipeline import _history_to_host
from repro.core.speculative import _apply_partial
from repro.launch.mesh import engine_lanes


def default_config(*, max_colors: int = 1024, n_iters: int = 8,
                   distance: int = 1, patience: int = 2,
                   scheme: str | None = None) -> PipelineConfig:
    """The service's default pipeline: quality preset shape — Random-X seed
    coloring + ND recoloring with an adaptive stop.

    ``scheme=None`` follows ``$REPRO_SCHEME`` (default ``"auto"``): each
    bucket picks sparse vs allgather at trace time from the modeled wire
    bytes, and the pow2-rung plan quantization keeps either choice
    compile-stable — there is no serving reason to force a scheme."""
    kw = {} if scheme is None else dict(scheme=scheme)
    return PipelineConfig(
        color=ColorConfig(max_colors=max_colors, superstep=512,
                          selection="random_x", random_x=10,
                          distance=distance, **kw),
        recolor=RecolorConfig(max_colors=max_colors, distance=distance, **kw),
        n_iters=n_iters, base_perm="nd", patience=patience)


# ------------------------------------------------------------------ clocks --

class WallClock:
    """Default time source: monotonic wall seconds (``time.perf_counter``).

    Any object with a ``now() -> float`` method is a valid clock — the
    scheduler never sleeps and never subtracts timestamps from different
    clocks, so a scripted ``FakeClock`` replays exact interleavings."""

    def now(self) -> float:
        return time.perf_counter()


class FakeClock:
    """Deterministic manual clock for scheduler tests and virtual-time
    benchmarks: ``now()`` returns the scripted time, ``advance`` moves it.
    Nothing in the service reads wall time when one of these is injected,
    so SLO sheds and latency accounting are exactly reproducible."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        assert dt >= 0, dt
        self._t += float(dt)
        return self._t


# --------------------------------------------------------- config + futures --

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Scheduler knobs (DESIGN.md §11).

    ``mode`` — ``"continuous"`` (engine lanes + admission control) or
    ``"flush"`` (the batch-synchronous router).  ``lanes`` — lane count
    per engine (rounded up to the batch mesh axis on a 2D mesh).
    ``chunk_iters`` — recoloring iterations per engine step; admission is
    interleaved between chunks, so smaller chunks admit sooner at the cost
    of more dispatches.  ``slo_s`` — latency SLO: a request whose queue
    age plus the engine's service-time estimate exceeds it is *shed*
    (``ShedError`` on its future) instead of admitted late; ``None``
    disables shedding (jobs defer until a lane frees).  ``max_queue`` —
    hard queue-depth bound; submits past it shed immediately.
    ``max_engines`` — live engine cap (idle LRU engines are evicted to
    make room).  ``solo_warm`` — keep the PR 6 hit path: a request whose
    solo program is already compiled dispatches immediately, skipping the
    engine (continuous mode) or the batch wave (flush mode); ``False``
    forces every request through engine lanes / batch waves — the pure
    flush-the-world shape the open-loop bench compares against.
    """

    mode: str = "continuous"
    lanes: int = 4
    chunk_iters: int = 2
    slo_s: float | None = None
    max_queue: int = 1024
    max_engines: int = 8
    solo_warm: bool = True

    def __post_init__(self):
        assert self.mode in ("continuous", "flush"), self.mode
        assert self.lanes >= 1 and self.chunk_iters >= 1
        assert self.max_queue >= 1 and self.max_engines >= 1
        assert self.slo_s is None or self.slo_s > 0


class JobError(RuntimeError):
    """A request failed inside its lane (invalid coloring, color-id
    saturation, leaked sentinels).  Carried by the job's future; the
    engine keeps draining its other lanes."""

    def __init__(self, job_id: int, msg: str):
        super().__init__(msg)
        self.job_id = job_id


class ShedError(JobError):
    """Admission control rejected the request (queue bound or SLO)."""


class JobFuture:
    """Completion handle for one submitted request.

    Single-threaded by design: ``result()`` *drives* the service's
    scheduler (``poll``) until the job resolves — there is no background
    thread, so results are deterministic under a ``FakeClock``.  A shed
    or failed job raises its ``ShedError``/``JobError`` from ``result()``
    and exposes it via ``exception()``.
    """

    def __init__(self, svc: "ColoringService", job_id: int):
        self.id = job_id
        self._svc = svc
        self._out = None
        self._err: Exception | None = None
        self._resolved = False

    def done(self) -> bool:
        return self._resolved

    def exception(self) -> Exception | None:
        return self._err

    def result(self, max_polls: int = 100_000):
        polls = 0
        while not self._resolved:
            self._svc.poll()
            polls += 1
            if polls > max_polls:
                raise RuntimeError(f"request {self.id} did not resolve in "
                                   f"{max_polls} polls")
        if self._err is not None:
            raise self._err
        return self._out

    def _resolve(self, out, err: Exception | None):
        self._out, self._err, self._resolved = out, err, True


def _graph_fingerprint(g: Graph) -> str:
    """Content hash of a graph — the partition-memo key."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(g.n).tobytes())
    h.update(np.ascontiguousarray(g.indptr).tobytes())
    h.update(np.ascontiguousarray(g.indices).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class _Job:
    id: int
    graph: Graph
    marked: np.ndarray | None
    t_submit: float = 0.0
    deferred: bool = False       # counted into n_deferred at most once


@dataclasses.dataclass
class _Entry:
    """Memoized per-unique-graph dispatch state (keyed by content hash)."""
    pg: object          # PartitionedGraph (original dims)
    bucket: object      # its one-graph GraphBucket (pow2-padded)
    signature: object   # the bucket's PlanSignature (batch-lane grouping)
    solo_sig: object    # the padded member's pipeline_sim/_sharded signature
    order: object       # visit order for the padded member (np array)
    exact_sig: object   # the original dims' pipeline signature (hot path)
    exact_order: object  # visit order for the original partition
    # engine-padded (member, order) per engine dims — lane admission of a
    # repeat graph pays no re-pad / re-order
    engine_members: dict = dataclasses.field(default_factory=dict)

    @property
    def member(self):
        """The pow2-padded partition the solo path dispatches."""
        return self.bucket.members[0]


# ----------------------------------------------------------------- engine --

@dataclasses.dataclass
class _LaneJob:
    job: _Job
    member: object      # engine-padded PartitionedGraph
    t_admit: float


class _Engine:
    """One long-lived continuous-batching engine (DESIGN.md §11).

    Holds ``B`` lanes of stacked device buffers for one compiled
    ``(engine_init_program, engine_step_program)`` pair: fixed padded
    dims, fixed static exchange schedule, fixed resolved config.  Lane
    lifecycle: **empty** (no job; carry frozen at ``it = K+1`` so the
    step body is a select-masked no-op) → **running** (admitted request's
    arrays + request-folded key swapped in, fresh init carry) → **done**
    (adaptive stop tripped; drained to a result, back to empty).  The
    step program *donates* the carry, so the engine owns exactly one
    generation of lane state.

    Sim layout stacks lanes on axis 0 (``(B, P, ...)``); on a mesh the
    lanes ride axis 1 (``(P, B, ...)``, ``run_sharded_many``) and shard
    over the batch mesh axis.
    """

    def __init__(self, svc: "ColoringService", entry: _Entry,
                 cfg: PipelineConfig, eid: int):
        m = entry.member
        self.svc = svc
        self.cfg = cfg                     # resolved: never "auto"
        self.eid = eid
        self.P, self.halo = m.P, m.halo
        self.dims = dict(n_local_max=m.n_local_max, max_ghost=m.max_ghost,
                         max_boundary=m.max_boundary,
                         m_local_max=m.m_local_max, maxd=m.maxd,
                         maxd2=m.maxd2)
        self.id_dtypes = (m.gvid.dtype, m.prio.dtype)
        self.sparse = cfg.needs_sparse_plan
        self.static = m.comm_plan.static if self.sparse else None
        self.mesh = svc.mesh
        self.B = engine_lanes(self.mesh, svc.serve.lanes)
        self._lax = 0 if self.mesh is None else 1   # lane axis of buffers
        self.lanes: list[_LaneJob | None] = [None] * self.B
        self.n_running = 0
        self._arrs = self._carry = self._cstats = None
        self._lane_rkeys: list = [None] * self.B
        self.ewma_job_s: float | None = None
        self.last_used = svc._clock.now()

    # ------------------------------------------------------------ admission --

    def accepts(self, entry: _Entry, cfg: PipelineConfig) -> bool:
        """Admission gate: can this engine run ``entry`` bitwise?

        The member must pad into the engine's dims, agree on P / halo /
        resolved config / id-policy dtypes, and (sparse scheme) its comm
        plan must embed into the engine's static exchange schedule
        (``core.plan_fits`` — padding preserves the plan, so probing the
        unpadded member decides for the padded one too)."""
        m = entry.member
        if (m.P, m.halo) != (self.P, self.halo) or cfg != self.cfg:
            return False
        if (m.gvid.dtype, m.prio.dtype) != self.id_dtypes:
            return False
        if any(getattr(m, k) > v for k, v in self.dims.items()):
            return False
        if self.sparse and not plan_fits(m.comm_plan, self.static):
            return False
        return True

    def free_lane(self) -> int | None:
        for b, ln in enumerate(self.lanes):
            if ln is None:
                return b
        return None

    def estimate_s(self) -> float:
        """Cost-model service-time estimate for one more request: the
        EWMA of observed lane admit→drain times (0 until observed —
        deterministically so under a ``FakeClock`` that never advances)."""
        return self.ewma_job_s or 0.0

    def admit(self, job: _Job, b: int, entry: _Entry, now: float) -> None:
        """Swap ``job`` into freed lane ``b`` without recompiling: pad the
        member to the engine dims, remap its sparse plan onto the engine
        schedule, run the cached init program (initial coloring → recolor
        carry) and scatter arrays + carry + request-folded key into the
        lane buffers.  Running neighbor lanes are untouched — their next
        step reads bitwise the same carry they would have anyway."""
        svc = self.svc
        dims_key = tuple(sorted(self.dims.items()))
        cached = entry.engine_members.get(dims_key)
        if cached is None:
            # the padded member, its visit order and its device-side input
            # arrays are the same for every admission of this graph into
            # this engine shape — build them once, device-resident
            member = pad_partition(entry.member, **self.dims)
            order = compute_order(member, svc.order_kind)
            arrs = {k: jnp.asarray(v)
                    for k, v in member.arrays(sparse=False).items()}
            if self.sparse:
                arrs.update({k: jnp.asarray(v) for k, v in
                             remap_plan_arrays(member, self.static).items()})
            cached = entry.engine_members[dims_key] = (member, order, arrs)
        member, order, arrs = cached
        marked = (svc._marked_blocks(member, job.marked)
                  if self.cfg.color.partial else None)
        order = jnp.asarray(_apply_partial(order, self.cfg.color, marked))
        cks, rks = svc._keys([job])
        init = engine_init_program(self.P, self.cfg, self.static, arrs,
                                   mesh=self.mesh)
        carry, cstats = init(arrs, order, cks[0])
        if self._arrs is None:
            self._alloc(arrs, carry, cstats)
        self._put(b, arrs, carry, cstats)
        self._lane_rkeys[b] = rks[0]
        # never-admitted lanes need *some* key to stack; they are frozen
        # (it = K+1) so the step body select-masks whatever this produces
        self._lane_rkeys = [rks[0] if k is None else k
                            for k in self._lane_rkeys]
        self.lanes[b] = _LaneJob(job, member, now)
        self.n_running += 1
        self.last_used = now

    def _alloc(self, arrs, carry, cstats) -> None:
        """First admission: replicate the lane's buffers across B lanes,
        then freeze every lane via ``it = K+1`` (past the stop, so the
        body select-masks them) until a job is scattered in."""
        rep = lambda x: jnp.repeat(jnp.expand_dims(x, self._lax), self.B,
                                   axis=self._lax)
        self._arrs = jax.tree.map(rep, arrs)
        stacked = jax.tree.map(rep, carry)
        it_off = jnp.full_like(stacked[1], self.cfg.n_iters + 1)
        self._carry = (stacked[0], it_off) + tuple(stacked[2:])
        self._cstats = jax.tree.map(rep, cstats)

    def _put(self, b: int, arrs, carry, cstats) -> None:
        """One donated dispatch writes the whole lane swap (scattering the
        ~30 buffers eagerly would cost a device round-trip per buffer)."""
        prog = engine_put_program(self.P, self.cfg, self.static, arrs,
                                  self.B, mesh=self.mesh)
        self._arrs, self._carry, self._cstats = prog(
            (self._arrs, self._carry, self._cstats),
            (arrs, carry, cstats), b)

    # ------------------------------------------------------------- stepping --

    def step(self) -> np.ndarray:
        """Advance every lane by ``chunk_iters`` fused iterations (one
        cached dispatch, carry donated).  Returns the per-lane done mask —
        the poll loop's only host sync."""
        prog = engine_step_program(self.P, self.cfg, self.static,
                                   self._arrs, self.B,
                                   self.svc.serve.chunk_iters,
                                   mesh=self.mesh)
        keys = jnp.stack(self._lane_rkeys)
        self._carry, done = prog(self._arrs, self._carry, keys)
        done = np.asarray(jax.device_get(done))
        return done.all(axis=1) if self._lax == 0 else done.all(axis=0)

    def drain(self, done: np.ndarray, now: float, results: dict) -> None:
        """Unpack every done running lane to a result and free it.

        Fault isolation: a lane that leaked uncolored sentinels, tripped
        ``find_first_zero`` saturation (``n_out_of_range``) or produced an
        invalid coloring fails *only its own job* — the error lands on
        that job's future and the engine keeps running its other lanes."""
        svc = self.svc
        for b in range(self.B):
            ln = self.lanes[b]
            if ln is None or not done[b]:
                continue
            take = ((lambda x: x[b]) if self._lax == 0
                    else (lambda x: x[:, b]))
            got = jax.device_get(dict(
                view=take(self._carry[0]), it=take(self._carry[1]),
                hist=take(self._carry[4]),
                cstats={k: take(v) for k, v in self._cstats.items()}))
            self.lanes[b] = None
            self.n_running -= 1
            self.last_used = now
            dt = now - ln.t_admit
            self.ewma_job_s = (dt if self.ewma_job_s is None
                               else 0.7 * self.ewma_job_s + 0.3 * dt)
            member = ln.member
            view = np.asarray(got["view"])
            history = _history_to_host(np.asarray(got["hist"]))
            colors = member.gather_global_colors(view[:, :member.n_local_max])
            out = dict(
                colors=colors,
                n_colors=(history[-1]["n_colors_distinct"] if history else
                          int(got["cstats"]["n_colors_distinct"].max())),
                color={k: int(v.max()) for k, v in got["cstats"].items()},
                history=history, n_iters_run=int(got["it"].max()) - 1,
                bucket=self.eid, route="engine", member=member, cfg=self.cfg,
                latency_s=now - ln.job.t_submit)
            err = None
            if (colors <= 0).any():
                err = (f"request {ln.job.id}: lane leaked "
                       f"{int((colors <= 0).sum())} uncolored sentinels")
            elif (any(row["n_out_of_range"] for row in history)
                  or int(got["cstats"].get("n_out_of_range",
                                           np.int32(0)).max()) > 0):
                err = (f"request {ln.job.id}: color-id saturation "
                       f"(find_first_zero past max_colors="
                       f"{self.cfg.recolor.max_colors})")
            if svc.validate or err:
                out["check"] = check_coloring(
                    ln.job.graph, np.maximum(colors, 1),
                    distance=self.cfg.recolor.distance, marked=ln.job.marked)
                if err:
                    out["check"] = dict(out["check"], valid=False)
                elif not out["check"]["valid"]:
                    err = (f"request {ln.job.id}: invalid coloring "
                           f"({out['check']})")
            if err:
                out["error"] = err
                svc._fail(ln.job, out, err, results)
            else:
                svc._complete(ln.job, out, results)
                svc._n_lane += 1


class ColoringService:
    """Queue graphs, color them via the continuous scheduler, return by id.

    ``submit`` enqueues a ``core.Graph`` (plus an optional per-vertex
    ``marked`` mask when the config is partial) and returns a request id;
    ``submit_async`` additionally returns the request's ``JobFuture``.
    In continuous mode (``ServeConfig.mode``, the default) ``poll`` runs
    one scheduler step — admit queued requests into free engine lanes
    (or solo-dispatch warm ones, or shed per the SLO), advance every
    active engine one chunk, drain finished lanes — and returns the
    results that completed during the call; ``flush`` polls until the
    queue and all lanes drain and returns every result since the last
    flush.  In ``"flush"`` mode the PR 6 batch-synchronous router is used
    unchanged.

    Each result carries ``colors`` ``(n,)`` 1-based, ``n_colors``, the
    per-iteration ``history``, ``n_iters_run``, the dispatch ``route``
    (``"engine"``/``"solo"``/``"batch"``), its ``latency_s`` (continuous:
    arrival→completion on the service clock; flush: wall time of the
    dispatch) and (``validate=True``) a ``check_coloring`` report.
    Failed jobs appear with an ``"error"`` key and raise ``JobError``
    from their future; shed jobs never produce a result — their future
    raises ``ShedError``.

    Request RNG keys fold the *request id* into the config seeds, so a
    request's coloring does not depend on which route, lane or batch
    position served it.  ``mesh=None`` uses the sim executor; a built
    mesh or ``launch.mesh.MeshSpec`` routes collectives over its shard
    axis, and a 2D ``MeshSpec.coloring(P, batch)`` mesh shards engine
    lanes over the ``batch`` axis.  ``clock`` injects a time source
    (``FakeClock`` for deterministic tests).  ``stats()`` exposes the
    scheduler counters and the process-wide program-cache counters.
    """

    def __init__(self, *, P: int = 4, cfg: PipelineConfig | None = None,
                 order_kind: str = ordering.INTERNAL_FIRST, mesh=None,
                 max_batch: int = 64, validate: bool = False, seed: int = 0,
                 memo_graphs: int = 256, serve: ServeConfig | None = None,
                 clock=None):
        self.P = P
        self.cfg = cfg or default_config()
        self.order_kind = order_kind
        if mesh is not None and hasattr(mesh, "build"):   # a MeshSpec
            mesh = mesh.build()
        self.mesh = mesh
        self.max_batch = max_batch
        self.validate = validate
        self.seed = seed
        self.serve = serve or ServeConfig()
        self._clock = clock or WallClock()
        self._queue: list[_Job] = []
        self._next_id = 0
        self._memo: OrderedDict[str, _Entry] = OrderedDict()
        self._memo_max = memo_graphs
        self._engines: list[_Engine] = []
        self._engine_seq = 0
        self._futures: OrderedDict[int, JobFuture] = OrderedDict()
        self._results: dict[int, dict] = {}
        self._n_solo = self._n_batch = self._n_lane = 0
        self._n_shed = self._n_deferred = self._n_failed = 0
        self._memo_hits = 0

    @property
    def pending(self) -> int:
        """Jobs the service still owes a resolution: queued + running
        lanes (shed/failed/completed jobs are resolved, not pending)."""
        return len(self._queue) + sum(e.n_running for e in self._engines)

    def submit(self, g: Graph, *, marked: np.ndarray | None = None) -> int:
        """Enqueue one graph; returns the request id results key on.

        Continuous mode applies the queue-depth bound here: past
        ``max_queue`` the request is shed immediately (its future raises
        ``ShedError``; the returned id is still valid for ``future``)."""
        assert self.cfg.color.partial == (marked is not None), (
            "marked= requires (and is required by) a partial color config")
        job = _Job(self._next_id, g, marked, t_submit=self._clock.now())
        self._next_id += 1
        if (self.serve.mode == "continuous"
                and len(self._queue) >= self.serve.max_queue):
            self._shed(job, f"queue depth {len(self._queue)} at bound "
                            f"max_queue={self.serve.max_queue}")
            return job.id
        self._queue.append(job)
        return job.id

    def submit_async(self, g: Graph, *,
                     marked: np.ndarray | None = None) -> JobFuture:
        """``submit`` + the request's future."""
        return self.future(self.submit(g, marked=marked))

    def future(self, job_id: int) -> JobFuture:
        """The ``JobFuture`` for a submitted request id."""
        assert 0 <= job_id < self._next_id, f"unknown request {job_id}"
        fut = self._futures.get(job_id)
        if fut is None:
            fut = self._futures[job_id] = JobFuture(self, job_id)
            out = self._results.get(job_id)
            if out is not None:      # already completed before first lookup
                err = out.get("error")
                fut._resolve(out, JobError(job_id, err) if err else None)
        return fut

    def stats(self) -> dict:
        """Scheduler + program-cache counters (cache stats process-wide).

        ``solo``/``batch``/``lane`` count completions by route;
        ``n_shed``/``n_deferred``/``n_failed`` count admission-control
        rejections, jobs that waited at least one poll for a lane, and
        per-lane failures; ``queued``/``running`` snapshot the states
        ``pending`` sums."""
        return dict(solo=self._n_solo, batch=self._n_batch,
                    lane=self._n_lane, n_shed=self._n_shed,
                    n_deferred=self._n_deferred, n_failed=self._n_failed,
                    queued=len(self._queue),
                    running=sum(e.n_running for e in self._engines),
                    engines=len(self._engines),
                    memo_hits=self._memo_hits, memo_size=len(self._memo),
                    signatures=len({e.signature
                                    for e in self._memo.values()}),
                    **program_cache_stats())

    def prewarm(self, samples) -> float:
        """Compile the one-lane programs for the given traffic samples.

        ``samples`` — representative ``core.Graph`` instances (e.g. one per
        expected shape bucket).  Each still-cold sample is dispatched once
        per missing solo program — the pow2-padded member's (shared by
        every later same-signature request) and the sample's exact-dims
        one (the cheapest dispatch for repeat-content traffic) — so
        steady-state requests take the hit path from their first flush.
        Returns the wall seconds spent; already-warm samples cost cache
        probes only.
        """
        t0 = time.perf_counter()
        for g in samples:
            e = self._entry(g)
            marked = (np.zeros(g.n, dtype=bool)
                      if self.cfg.color.partial else None)
            if not program_cache_contains(e.solo_sig):
                self._run_solo(_Job(0, g, marked), e, e.member, e.order)
            if not program_cache_contains(e.exact_sig):
                self._run_solo(_Job(0, g, marked), e, e.pg, e.exact_order)
        return time.perf_counter() - t0

    # --------------------------------------------------- continuous scheduler --

    def poll(self) -> dict[int, dict]:
        """One scheduler step; returns results completed during the call.

        Order: (1) admission pass over the FIFO queue — warm solo
        dispatch, lane admission into a compatible engine (creating one
        under the ``max_engines`` cap), or shed/defer per the SLO;
        (2) every engine with running lanes advances one ``chunk_iters``
        step; (3) finished lanes drain to results and free up.  Admission
        precedes stepping, so a request admitted this poll overlaps its
        neighbors' very next chunk — that interleaving is what the
        lane-bitwise-equality property pins as inert."""
        results: dict[int, dict] = {}
        now = self._clock.now()
        progressed = False
        still: list[_Job] = []
        for job in self._queue:
            if self._admit_one(job, now, results) == "defer":
                still.append(job)
            else:
                progressed = True
        self._queue = still
        for eng in self._engines:
            if eng.n_running:
                done = eng.step()
                eng.drain(done, self._clock.now(), results)
                progressed = True
        if self._queue and not progressed:
            # deferral requires a busy lane somewhere; with nothing
            # running this cannot resolve — surface it instead of spinning
            raise RuntimeError(
                "scheduler stalled: every queued job deferred with no "
                "lane running (lanes/max_engines too small for the mix?)")
        return results

    def flush(self) -> dict[int, dict]:
        """Drain everything; returns every result since the last flush.

        Continuous mode polls until the queue and all lanes are empty
        (results completed by earlier ``poll`` calls are included);
        ``"flush"`` mode runs the batch-synchronous router waves."""
        if self.serve.mode == "flush":
            return self._flush_waves()
        polls = 0
        while self.pending:
            self.poll()
            polls += 1
            assert polls < 1_000_000, "flush did not drain"
        out, self._results = self._results, {}
        return out

    def _admit_one(self, job: _Job, now: float, results: dict) -> str:
        """Admission decision for one queued request (DESIGN.md §11):
        ``"solo"`` | ``"lane"`` | ``"shed"`` | ``"defer"``."""
        e = self._entry(job.graph)
        cfg = resolve_pipeline_cfg(e.member, self.cfg)
        sc = self.serve
        if sc.solo_warm and (program_cache_contains(e.exact_sig)
                             or program_cache_contains(e.solo_sig)):
            r = self._solo_dispatch(job, e)
            out = dict(colors=r["colors"],
                       n_colors=(r["history"][-1]["n_colors_distinct"]
                                 if r["history"]
                                 else r["color"]["n_colors_distinct"]),
                       color=r["color"], history=r["history"],
                       n_iters_run=r["n_iters_run"], bucket=r["bucket"],
                       route="solo",
                       latency_s=self._clock.now() - job.t_submit)
            err = None
            if self.validate:
                out["check"] = check_coloring(
                    job.graph, r["colors"],
                    distance=self.cfg.recolor.distance, marked=job.marked)
                if not out["check"]["valid"]:
                    err = (f"request {job.id}: invalid coloring "
                           f"({out['check']})")
            if err:
                out["error"] = err
                self._fail(job, out, err, results)
            else:
                self._complete(job, out, results)
                self._n_solo += 1
            return "solo"
        m = e.member
        nat = dict(n_local_max=m.n_local_max, max_ghost=m.max_ghost,
                   max_boundary=m.max_boundary, m_local_max=m.m_local_max,
                   maxd=m.maxd, maxd2=m.maxd2)
        fits = [g for g in self._engines if g.accepts(e, cfg)]
        # best-fit admission: an exact-dims engine first, else a fresh
        # tight engine — padding a small member up into an oversized
        # engine makes every one of its chunks (and, on serialized sim
        # lanes, every co-resident job's wall clock) pay the big dims.
        # Pad-up is the last resort, tightest fitting engine first, when
        # the cap blocks a new engine.
        eng = next((g for g in fits if g.dims == nat), None)
        if eng is None:
            eng = self._new_engine(e, cfg)
        if eng is None and fits:
            eng = min(fits, key=lambda g: (np.prod(
                [float(v) for v in g.dims.values()]), g.eid))
        b = eng.free_lane() if eng is not None else None
        if b is not None:
            eng.admit(job, b, e, now)
            return "lane"
        est = eng.estimate_s() if eng is not None else 0.0
        if sc.slo_s is not None and (now - job.t_submit) + est > sc.slo_s:
            self._shed(job, f"admission control: queue age "
                            f"{now - job.t_submit:.3f}s + estimate "
                            f"{est:.3f}s exceeds SLO {sc.slo_s}s")
            return "shed"
        if not job.deferred:
            job.deferred = True
            self._n_deferred += 1
        return "defer"

    def _new_engine(self, e: _Entry, cfg: PipelineConfig) -> _Engine | None:
        """Create an engine for ``e``'s shape, evicting the LRU *idle*
        engine when at the cap; ``None`` when every engine is busy."""
        if len(self._engines) >= self.serve.max_engines:
            idle = [g for g in self._engines if g.n_running == 0]
            if not idle:
                return None
            self._engines.remove(min(idle, key=lambda g: g.last_used))
        eng = _Engine(self, e, cfg, self._engine_seq)
        self._engine_seq += 1
        self._engines.append(eng)
        return eng

    def _complete(self, job: _Job, out: dict, results: dict) -> None:
        results[job.id] = out
        self._results[job.id] = out
        self._resolve_future(job.id, out, None)

    def _fail(self, job: _Job, out: dict, err: str, results: dict) -> None:
        results[job.id] = out
        self._results[job.id] = out
        self._n_failed += 1
        self._resolve_future(job.id, out, JobError(job.id, err))

    def _shed(self, job: _Job, why: str) -> None:
        self._n_shed += 1
        self._resolve_future(job.id, None,
                             ShedError(job.id, f"request {job.id} shed: "
                                               f"{why}"))

    def _resolve_future(self, job_id: int, out, err) -> None:
        fut = self._futures.get(job_id)
        if fut is None:
            fut = self._futures[job_id] = JobFuture(self, job_id)
        fut._resolve(out, err)
        while len(self._futures) > 4096:
            oldest = next(iter(self._futures))
            if not self._futures[oldest].done():
                break
            del self._futures[oldest]

    # ------------------------------------------------------------ internals --

    @property
    def _halo(self) -> int:
        return 2 if self.cfg.recolor.distance == 2 else 1

    def _entry(self, g: Graph) -> _Entry:
        """Partition + bucket + signature, memoized by graph content."""
        fp = _graph_fingerprint(g)
        e = self._memo.get(fp)
        if e is not None:
            self._memo.move_to_end(fp)
            self._memo_hits += 1
            return e
        pg = partition_graph(g, self.P, seed=self.seed, halo=self._halo)
        bucket = bucket_graphs([pg])[0]
        sig = bucket_signature(bucket, self.cfg, mesh=self.mesh)
        member = bucket.members[0]
        e = _Entry(pg=pg, bucket=bucket, signature=sig,
                   solo_sig=plan_signature(member, self.cfg, mesh=self.mesh),
                   order=compute_order(member, self.order_kind),
                   exact_sig=plan_signature(pg, self.cfg, mesh=self.mesh),
                   exact_order=compute_order(pg, self.order_kind))
        self._memo[fp] = e
        while len(self._memo) > self._memo_max:
            self._memo.popitem(last=False)
        return e

    def _marked_blocks(self, pg, marked_g):
        """Global per-vertex mask -> the (P, n_local_max) block layout."""
        out = np.zeros((pg.P, pg.n_local_max), dtype=bool)
        for p in range(pg.P):
            nl, lo = int(pg.n_local[p]), int(pg.offs[p])
            out[p, :nl] = marked_g[lo:lo + nl]
        return out

    def _keys(self, jobs):
        """Request-id-folded per-graph keys: route-independent results."""
        ck = jax.random.key(self.cfg.color.seed)
        rk = jax.random.key(self.cfg.seed)
        return ([jax.random.fold_in(ck, j.id) for j in jobs],
                [jax.random.fold_in(rk, j.id) for j in jobs])

    def _solo_dispatch(self, job, e: _Entry) -> dict:
        """One request through the *unbatched* fused program — the hit path.

        No batch axis, no stacking, no unpacking: warm same-program latency
        is one cached-program device dispatch (bitwise equal to the batch
        lane — padding is inert and the request-id-folded keys are route-
        independent).  Prefers the original-dims program (no padding
        compute; ``prewarm`` compiles it for sample graphs) and falls back
        to the pow2-padded member's, which fresh same-signature graphs
        share."""
        if program_cache_contains(e.exact_sig):
            tgt, order = e.pg, e.exact_order
        else:
            tgt, order = e.member, e.order
        return self._run_solo(job, e, tgt, order)

    def _run_solo(self, job, e: _Entry, tgt, order) -> dict:
        cks, rks = self._keys([job])
        marked = (self._marked_blocks(tgt, job.marked)
                  if self.cfg.color.partial else None)
        run = (pipeline_sim if self.mesh is None else
               lambda *a, **kw: pipeline_sharded(a[0], a[1], a[2], self.mesh,
                                                 **kw))
        view, res = run(tgt, order, self.cfg, marked=marked,
                        color_key=cks[0], recolor_key=rks[0])
        view = np.asarray(view)
        return dict(
            colors=e.pg.gather_global_colors(view[:, :e.pg.n_local_max]),
            color=res["color"], history=res["history"],
            n_iters_run=res["n_iters_run"], bucket=0)

    def _dispatch(self, jobs, entries=None, buckets=None):
        """One ``color_many`` call for ``jobs`` (solo entry or cold group)."""
        pgs = [e.pg for e in entries] if entries is not None else [
            partition_graph(j.graph, self.P, seed=self.seed, halo=self._halo)
            for j in jobs]
        if entries is not None and buckets is None:
            # reuse the memoized bucket object whenever its indices already
            # line up (always true for solo dispatch) — its union plan and
            # stacked arrays are cached on the instance, so a warm request
            # pays no host-side re-stack
            buckets = [e.bucket if e.bucket.indices == (i,) else
                       dataclasses.replace(e.bucket, indices=(i,))
                       for i, e in enumerate(entries)]
        marked = None
        if self.cfg.color.partial:
            marked = [self._marked_blocks(pg, j.marked)
                      for pg, j in zip(pgs, jobs)]
        cks, rks = self._keys(jobs)
        run = (color_many if self.mesh is None
               else lambda *a, **kw: color_many_sharded(
                   a[0], a[1], self.mesh, **kw))
        # pad_batch: pow2 batch lanes keep program shapes stable as the
        # queue depth fluctuates, so steady-state flushes stay compiled
        return run(pgs, self.cfg, orders=self.order_kind, marked=marked,
                   color_keys=cks, recolor_keys=rks, buckets=buckets,
                   pad_batch=True)

    def _finish(self, job, r, latency, route, results):
        out = dict(colors=r["colors"],
                   n_colors=(r["history"][-1]["n_colors_distinct"]
                             if r["history"]
                             else r["color"]["n_colors_distinct"]),
                   history=r["history"], n_iters_run=r["n_iters_run"],
                   bucket=r["bucket"], route=route, latency_s=latency)
        if self.validate:
            out["check"] = check_coloring(
                job.graph, r["colors"],
                distance=self.cfg.recolor.distance, marked=job.marked)
            assert out["check"]["valid"], (job.id, out["check"])
        results[job.id] = out
        self._resolve_future(job.id, out, None)

    def _flush_waves(self) -> dict[int, dict]:
        """Route and dispatch the queue in waves of ``max_batch``."""
        results: dict[int, dict] = {}
        while self._queue:
            jobs, self._queue = (self._queue[:self.max_batch],
                                 self._queue[self.max_batch:])
            pairs = [(j, self._entry(j.graph)) for j in jobs]

            def _warm(e):
                # solo_warm=False pins the pure flush-the-world wave
                # router (every request rides a batch wave) — the
                # continuous scheduler's open-loop comparator
                return self.serve.solo_warm and (
                    program_cache_contains(e.solo_sig)
                    or program_cache_contains(e.exact_sig))

            warm = [(j, e) for j, e in pairs if _warm(e)]
            cold = [(j, e) for j, e in pairs if not _warm(e)]
            # hit path: the program is compiled — serve each request now,
            # individually (latency = one device dispatch, no batch wait)
            for j, e in warm:
                t0 = self._clock.now()
                out = self._solo_dispatch(j, e)
                self._finish(j, out, self._clock.now() - t0, "solo",
                             results)
                self._n_solo += 1
            # miss path: group the new shapes so each fresh program
            # compiles (and dispatches) once for its whole sub-batch.
            # Grouping by *solo signature* (not raw dims) makes the group's
            # padded dims and union plan equal every member's own — pow2 of
            # a max is the max of pow2s — so the same traffic shape produces
            # the same batch program on every future flush.
            groups: OrderedDict = OrderedDict()
            for j, e in cold:
                groups.setdefault(e.signature, []).append((j, e))
            for sub in groups.values():
                bucket = bucket_graphs([e.pg for _, e in sub])[0]
                t0 = self._clock.now()
                outs = self._dispatch([j for j, _ in sub],
                                      [e for _, e in sub], [bucket])
                lat = self._clock.now() - t0
                for (j, _), r in zip(sub, outs):
                    self._finish(j, r, lat, "batch", results)
                    self._n_batch += 1
        return results


def _traffic(n_graphs: int, scale_lo: int, scale_hi: int, seed: int):
    """A synthetic request mix: the three RMAT classes at mixed scales."""
    rng = np.random.default_rng(seed)
    gens = (rmat.rmat_er, rmat.rmat_good, rmat.rmat_bad)
    return [gens[i % 3](int(rng.integers(scale_lo, scale_hi + 1)), 8,
                        seed=int(rng.integers(1 << 30)))
            for i in range(n_graphs)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=16)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--scale-min", type=int, default=6)
    ap.add_argument("--scale-max", type=int, default=8)
    ap.add_argument("--max-colors", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=("continuous", "flush"),
                    default="continuous")
    ap.add_argument("--lanes", type=int, default=4)
    args = ap.parse_args()

    graphs = _traffic(args.graphs, args.scale_min, args.scale_max, args.seed)
    svc = ColoringService(
        P=args.p, validate=True,
        cfg=default_config(max_colors=args.max_colors, n_iters=args.iters),
        serve=ServeConfig(mode=args.mode, lanes=args.lanes))
    ids = [svc.submit(g) for g in graphs]

    t0 = time.time()
    res = svc.flush()                      # includes compile on first flush
    t_cold = time.time() - t0
    n_buckets = len({r["bucket"] for r in res.values()})
    # compile the one-lane programs for the shapes just seen, so
    # steady-state requests take the solo hit path from their first flush
    t_pre = svc.prewarm(graphs)
    # steady state: FRESH graphs still hit the compiled programs
    # (pow2 plan rungs + pow2 shapes + pow2 batch lanes)
    for g in _traffic(args.graphs, args.scale_min, args.scale_max,
                      args.seed + 1):
        svc.submit(g)
    t0 = time.time()
    res2 = svc.flush()
    t_warm = time.time() - t0
    lats = sorted(r["latency_s"] for r in res2.values())
    st = svc.stats()
    hit_rate = st["hits"] / max(st["hits"] + st["misses"], 1)

    print(f"served {len(ids)} graphs over {n_buckets} "
          f"{'engines' if args.mode == 'continuous' else 'buckets'} at "
          f"P={args.p}: cold {t_cold:.2f}s, prewarm {t_pre:.2f}s, "
          f"warm {t_warm:.3f}s "
          f"({len(ids) / max(t_warm, 1e-9):.1f} graphs/s)")
    print(f"routes solo={st['solo']} lane={st['lane']} batch={st['batch']} "
          f"shed={st['n_shed']} program-cache hit rate {hit_rate:.2f} "
          f"p50 {lats[len(lats) // 2] * 1e3:.1f}ms "
          f"p99 {lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e3:.1f}ms")
    for i in ids[:8]:
        r = res[i]
        print(f"  req {i}: {r['n_colors']} colors after "
              f"{r['n_iters_run']} RC iters (bucket {r['bucket']}, "
              f"valid={r['check']['valid']})")


if __name__ == "__main__":
    main()
