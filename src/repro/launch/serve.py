"""Batched serving driver: prefill a prompt batch, then greedy-decode.

CPU-scale:  python -m repro.launch.serve --arch qwen3-0.6b --smoke \
                --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_arch, plan_for_mesh, smoke_of
from repro.launch.mesh import make_local_mesh
from repro.models import decode_step, param_defs, prefill
from repro.models.layers import ParamDef
from repro.train.trainer import init_params_sharded

IS_DEF = lambda t: isinstance(t, ParamDef)  # noqa: E731


def serve(arch, mesh, plan, *, batch: int, prompt_len: int, gen: int,
          seed: int = 0, params=None):
    pdefs = param_defs(arch)
    specs = jax.tree.map(lambda d: plan.spec(d.dims, d.shape), pdefs,
                         is_leaf=IS_DEF)
    if params is None:
        params = init_params_sharded(pdefs, mesh, specs, seed)
    rng = np.random.default_rng(seed)
    batch_in = {"tokens": jnp.asarray(
        rng.integers(0, arch.vocab_size, (batch, prompt_len)), jnp.int32)}
    if arch.enc_dec:
        batch_in["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, arch.enc_len, arch.d_model)),
            jnp.float32)
    if arch.n_patches:
        batch_in["patch_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (batch, arch.n_patches, arch.d_model)),
            jnp.float32)
        batch_in["pos3"] = jnp.broadcast_to(
            jnp.arange(prompt_len, dtype=jnp.int32)[None, None],
            (3, batch, prompt_len))

    prefill_fn = jax.jit(lambda p, b: prefill(p, b, arch, plan, prompt_len))
    step_fn = jax.jit(lambda p, c, t: decode_step(p, c, t, arch, plan))

    with compat.set_mesh(mesh):
        t0 = time.time()
        cache, logits = prefill_fn(params, batch_in)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t_prefill = time.time() - t0
        out = [tok]
        t0 = time.time()
        for _ in range(gen - 1):
            cache, logits = step_fn(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    return tokens, dict(
        prefill_s=t_prefill, decode_s=t_decode,
        tok_per_s=batch * (gen - 1) / max(t_decode, 1e-9))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    arch = get_arch(args.arch)
    if args.smoke:
        arch = smoke_of(arch)
    mesh = make_local_mesh()
    plan = plan_for_mesh(mesh)
    tokens, stats = serve(arch, mesh, plan, batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen)
    print("generated shape:", tokens.shape)
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
