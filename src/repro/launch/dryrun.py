import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry run: lower + compile every (architecture × shape × mesh).

The two lines above MUST stay first: JAX locks the device count on first
init, and the production meshes need 512 placeholder host devices.

For every cell this script
  - builds ShapeDtypeStruct stand-ins (no allocation) with NamedShardings,
  - ``jit(step).lower(...)`` then ``.compile()`` under the mesh,
  - records ``memory_analysis()`` (per-device bytes — proves it fits),
    ``cost_analysis()`` (raw, body-once), and the loop-aware roofline
    parse of the partitioned HLO (see repro/roofline.py),
  - writes one JSON per cell to --out (default experiments/dryrun).

Also lowers the *coloring* core (the paper's contribution) over the full
mesh flattened to a 1-axis worker mesh — the production coloring config.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--coloring]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro import compat
from repro.configs import SHAPES, get_arch, list_archs, shape_applicable
from repro.launch.mesh import (make_coloring_mesh, make_production_mesh,
                               make_worker_mesh)
from repro.launch.steps import input_specs
from repro.roofline import analyze_hlo, model_flops, roofline_terms


def mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def dryrun_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
                out_dir: Path, force: bool = False) -> dict:
    tag = f"{arch_name}__{shape_name}__{mesh_tag(multi_pod)}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    rec: dict = dict(arch=arch_name, shape=shape_name,
                     mesh=mesh_tag(multi_pod), status="skipped", reason=why)
    if not ok:
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            fn, args = input_specs(arch, shape, mesh)
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = {}
            try:
                stats = compiled.memory_analysis()
                for f in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes"):
                    ma[f] = int(getattr(stats, f, 0))
                ma["total_per_device"] = (ma["argument_size_in_bytes"]
                                          + ma["temp_size_in_bytes"]
                                          + ma["output_size_in_bytes"]
                                          - ma["alias_size_in_bytes"])
            except Exception as e:  # pragma: no cover
                ma["error"] = str(e)

            ca = {}
            try:
                raw = compiled.cost_analysis()
                ca = {k: float(v) for k, v in raw.items()
                      if k in ("flops", "bytes accessed")}
            except Exception as e:  # pragma: no cover
                ca["error"] = str(e)

            hlo = compiled.as_text()
            analysis = analyze_hlo(hlo)
            terms = roofline_terms(analysis)
            mf = model_flops(arch, shape)
            rec.update(
                status="ok",
                n_chips=n_chips,
                lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
                memory_analysis=ma, cost_analysis_raw=ca,
                coll_count=analysis["coll_count"],
                coll_bytes=analysis["coll_bytes"],
                dynamic_whiles=analysis["dynamic_whiles"],
                roofline=terms,
                model_flops_global=mf,
                model_flops_per_chip=mf / n_chips,
                useful_flops_ratio=(mf / n_chips) / terms["flops"]
                if terms["flops"] else 0.0,
                hlo_bytes=len(hlo),
            )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def dryrun_coloring(*, multi_pod: bool, out_dir: Path,
                    force: bool = False) -> dict:
    """Lower the paper's distributed coloring over the production mesh."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (ColorConfig, RecolorConfig, color_spmd,
                            partition_graph, rmat)
    from repro.core.comm import run_sharded
    from repro.core.recolor import recolor_spmd
    from functools import partial

    tag = f"coloring__rmat18__{mesh_tag(multi_pod)}"
    out_path = out_dir / f"{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    P = 512 if multi_pod else 256
    mesh = make_worker_mesh(P)
    g = rmat.rmat_er(18, 8, seed=1)          # 262k vertices over 256/512 shards
    pg = partition_graph(g, P)
    plan = pg.comm_plan
    rec: dict = dict(arch="coloring", shape=f"rmat18_P{P}",
                     mesh=mesh_tag(multi_pod), status="skipped")
    t0 = time.time()
    try:
        arrs = {k: jnp.asarray(v) for k, v in pg.arrays().items()}
        order = jnp.zeros((P, pg.n_local_max), jnp.int32)
        key = jax.random.key(0)
        cfg = ColorConfig(max_colors=256, superstep=64, scheme="allgather")
        fn = partial(color_spmd, cfg=cfg, P_size=P)
        lowered = jax.jit(
            lambda a, o, k: run_sharded(fn, mesh, (a, o), (k,))).lower(
                arrs, order, key)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        analysis = analyze_hlo(hlo)
        # one recoloring iteration too
        rfn = partial(recolor_spmd, perm_kind="nd",
                      cfg=RecolorConfig(max_colors=256, scheme="allgather"),
                      P_size=P)
        view = jnp.zeros((P, pg.n_slots), jnp.int32)
        lowered_rc = jax.jit(
            lambda a, v, k: run_sharded(rfn, mesh, (a, v), (k,))).lower(
                arrs, view, key)
        compiled_rc = lowered_rc.compile()
        analysis_rc = analyze_hlo(compiled_rc.as_text())
        # beyond-paper: int16 wire payloads (DESIGN.md §6)
        rfn16 = partial(recolor_spmd, perm_kind="nd",
                        cfg=RecolorConfig(max_colors=256, wire16=True,
                                          scheme="allgather"), P_size=P)
        compiled_rc16 = jax.jit(
            lambda a, v, k: run_sharded(rfn16, mesh, (a, v), (k,))).lower(
                arrs, view, key).compile()
        analysis_rc16 = analyze_hlo(compiled_rc16.as_text())
        # sparse neighbour-to-neighbour scheme (DESIGN.md §2): modeled bytes
        # always; lowered too unless the round schedule is huge (one
        # collective per ppermute round in the HLO body)
        from repro.core.comm import allgather_bytes_per_exchange
        sparse_rec = dict(
            n_rounds=len(plan.shifts),
            modeled_bytes_per_exchange=plan.bytes_per_exchange(),
            padded_bytes_per_exchange=plan.bytes_per_exchange(padded=True),
            allgather_modeled_bytes_per_exchange=allgather_bytes_per_exchange(
                P, pg.max_boundary),
        )
        # the trace-time scheme decision + the compiled-program identity the
        # modeled byte gap is attributable to (DESIGN.md §2)
        from repro.core import plan_signature, resolve_scheme
        from repro.core.pipeline import PipelineConfig as _PCfg
        decision = resolve_scheme("auto", pg)
        sig = plan_signature(pg, _PCfg(
            color=ColorConfig(max_colors=256, superstep=64, scheme="auto"),
            recolor=RecolorConfig(max_colors=256, scheme="auto"),
            n_iters=4, patience=2))
        sparse_rec["scheme_decision"] = decision
        sparse_rec["plan_signature"] = sig.describe()
        print(f"[coloring P={P}] plan signature: {sig.describe()}")
        print(f"[coloring P={P}] trace-time scheme decision: {decision} "
              f"(sparse padded "
              f"{sparse_rec['padded_bytes_per_exchange']}B vs allgather "
              f"{sparse_rec['allgather_modeled_bytes_per_exchange']}B "
              f"per exchange)")
        if len(plan.shifts) <= 64:
            rfs = partial(recolor_spmd, perm_kind="nd",
                          cfg=RecolorConfig(max_colors=256, scheme="sparse"),
                          P_size=P, plan_static=plan.static)
            compiled_sp = jax.jit(
                lambda a, v, k: run_sharded(rfs, mesh, (a, v), (k,))).lower(
                    arrs, view, key).compile()
            sparse_rec["recolor_coll_bytes"] = analyze_hlo(
                compiled_sp.as_text())["coll_bytes"]
        else:
            sparse_rec["lowering"] = (
                f"skipped: {len(plan.shifts)} ppermute rounds")
        # fused pipeline (DESIGN.md §7): initial coloring + K recoloring
        # iterations resident in ONE program — the paper's headline
        # experiment with zero per-iteration host round-trips
        from repro.core.pipeline import PipelineConfig, color_then_recolor
        pcfg = PipelineConfig(
            color=ColorConfig(max_colors=256, superstep=64,
                              scheme="allgather"),
            recolor=RecolorConfig(max_colors=256, scheme="allgather"),
            n_iters=4, patience=2)
        pfn = partial(color_then_recolor, cfg=pcfg, P_size=P)
        t_pipe = time.time()
        compiled_pipe = jax.jit(
            lambda a, o, ck, rk: run_sharded(pfn, mesh, (a, o),
                                             (ck, rk))).lower(
                arrs, order, key, key).compile()
        analysis_pipe = analyze_hlo(compiled_pipe.as_text())
        pipeline_rec = dict(
            n_iters=pcfg.n_iters, patience=pcfg.patience,
            compile_s=round(time.time() - t_pipe, 2),
            coll_count=analysis_pipe["coll_count"],
            coll_bytes=analysis_pipe["coll_bytes"],
        )
        # 2D batch × shard mesh (DESIGN.md §10): the batched pipeline with
        # graph lanes sharded over the ``batch`` mesh axis and partitions
        # over ``workers`` — the weak-scaling serving layout.  batch=2 at
        # P=256 (uses all 512 host devices); the multi-pod cell keeps
        # batch=1 (512 shards already occupy every device) but still runs
        # the 2D program structure.
        from repro.core.comm import (batch_axis_of, mesh_axes,
                                     run_sharded_many, shard_axis_of)
        Bm = 1 if multi_pod else 2
        mesh2d = make_coloring_mesh(P, batch=Bm)
        axis2 = shard_axis_of(mesh2d)
        B = 2                                     # lanes (a multiple of Bm)
        arrs_b = {k: jnp.repeat(v[:, None], B, axis=1)
                  for k, v in arrs.items()}
        order_b = jnp.repeat(order[:, None], B, axis=1)
        keys_b = jax.random.split(key, B)
        pfn2 = jax.vmap(partial(color_then_recolor, cfg=pcfg, P_size=P,
                                axis=axis2,
                                lane_axes=(batch_axis_of(mesh2d),)))
        t_2d = time.time()
        compiled_2d = jax.jit(
            lambda a, o, k1, k2: run_sharded_many(
                pfn2, mesh2d, (a, o), (k1, k2), axis=axis2)).lower(
                    arrs_b, order_b, keys_b, keys_b).compile()
        analysis_2d = analyze_hlo(compiled_2d.as_text())
        mesh2d_rec = dict(
            axes=[[n, s] for n, s in mesh_axes(mesh2d)], batch_lanes=B,
            compile_s=round(time.time() - t_2d, 2),
            coll_count=analysis_2d["coll_count"],
            coll_bytes=analysis_2d["coll_bytes"],
        )
        print(f"[coloring P={P}] 2D mesh "
              f"{'×'.join(f'{n}={s}' for n, s in mesh_axes(mesh2d))}: "
              f"batched pipeline lowered, "
              f"{analysis_2d['coll_count']} collectives")
        rec.update(
            mesh2d=mesh2d_rec,
            status="ok", n_chips=P, compile_s=round(time.time() - t0, 2),
            color_coll_count=analysis["coll_count"],
            color_coll_bytes=analysis["coll_bytes"],
            recolor_coll_count=analysis_rc["coll_count"],
            recolor_coll_bytes=analysis_rc["coll_bytes"],
            recolor_wire16_coll_bytes=analysis_rc16["coll_bytes"],
            sparse=sparse_rec,
            pipeline=pipeline_rec,
            graph=dict(n=g.n, m=g.m, P=P,
                       n_local_max=pg.n_local_max,
                       max_boundary=pg.max_boundary,
                       max_ghost=pg.max_ghost,
                       max_send=plan.max_send),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--coloring", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    if args.coloring:
        for mp in meshes:
            rec = dryrun_coloring(multi_pod=mp, out_dir=out_dir,
                                  force=args.force)
            print(json.dumps(rec)[:240])
        if not (args.all or args.arch):
            return

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = dryrun_cell(arch, shape, multi_pod=mp, out_dir=out_dir,
                                  force=args.force)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"dom={r['bottleneck']} "
                             f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
                             f"x={r['collective_s']:.3f}s")
                elif status == "error":
                    extra = rec.get("error", "")[:120]
                print(f"[{time.time()-t0:7.1f}s] {arch:22s} {shape:12s} "
                      f"{mesh_tag(mp):10s} {status:8s} {extra}", flush=True)


if __name__ == "__main__":
    main()
