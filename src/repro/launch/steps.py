"""Jit-ready step functions + ShapeDtypeStruct input builders per cell.

``input_specs(arch, shape, mesh)`` returns (step_fn, example tree of
ShapeDtypeStructs with NamedShardings, in_shardings tree) for every
(architecture × input-shape) cell — weak-type-correct, shardable, and never
allocating device memory. The dry-run lowers exactly these.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import SHAPES, ArchConfig, ShapeConfig, plan_for_mesh
from repro.models import cache_defs, decode_step, loss_fn, param_defs, prefill
from repro.models.layers import ParamDef
from repro.train.optimizer import OptConfig, adamw_update, opt_state_defs

IS_DEF = lambda t: isinstance(t, ParamDef)  # noqa: E731


def sds_tree(defs, mesh, plan):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, d.dtype,
            sharding=NamedSharding(mesh, plan.spec(d.dims, d.shape))),
        defs, is_leaf=IS_DEF)


def shardings_of(sds):
    return jax.tree.map(lambda s: s.sharding, sds)


def batch_defs(cfg: ArchConfig, shape: ShapeConfig, *, decode: bool = False):
    """ParamDef table for one batch (tokens + modality stubs)."""
    B = shape.global_batch
    S = 1 if decode else shape.seq_len
    defs: dict[str, Any] = {
        "tokens": ParamDef((B, S), ("batch", None), dtype="int32"),
    }
    if shape.is_train:
        defs["labels"] = ParamDef((B, S), ("batch", None), dtype="int32")
    if cfg.enc_dec and not decode:
        defs["enc_embeds"] = ParamDef((B, cfg.enc_len, cfg.d_model),
                                      ("batch", None, None),
                                      dtype=cfg.compute_dtype)
    if cfg.n_patches and not decode:
        defs["patch_embeds"] = ParamDef((B, cfg.n_patches, cfg.d_model),
                                        ("batch", None, None),
                                        dtype=cfg.compute_dtype)
        defs["pos3"] = ParamDef((3, B, S), (None, "batch", None),
                                dtype="int32")
    return defs


def _split_micro(x, M: int, batch_axis: int = 0):
    """(…, B, …) -> (M, …, B/M, …) microbatch leading axis."""
    B = x.shape[batch_axis]
    assert B % M == 0, f"batch {B} not divisible by grad_accum {M}"
    x = jnp.moveaxis(x, batch_axis, 0)
    x = x.reshape((M, B // M) + x.shape[1:])
    return jnp.moveaxis(x, 1, batch_axis + 1) if batch_axis else x


def make_train_step(cfg: ArchConfig, plan, opt_cfg: OptConfig):
    pdefs = param_defs(cfg)
    grad_specs = jax.tree.map(lambda d: plan.spec(d.dims, d.shape), pdefs,
                              is_leaf=IS_DEF)

    def constrain_grads(grads):
        # pin gradients to the parameter sharding: the DP reduction lowers to
        # reduce-scatter (1x wire) instead of a replicated all-reduce (2x)
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s)
            if any(e is not None for e in s) else g, grads, grad_specs)

    M = cfg.grad_accum

    def train_step(params, opt_state, batch):
        if M <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg, plan)
            grads = constrain_grads(grads)
        else:
            # gradient accumulation: scan over microbatches, f32 sharded
            # accumulators — activation memory scales 1/M.
            # ZeRO-2 twist: non-expert weights are all-gathered ONCE per step
            # (constrained to a spec with the fsdp dim dropped) instead of
            # once per microbatch — 1/M the FSDP all-gather traffic for
            # ~2.6 GB of temp on deepseek (see EXPERIMENTS.md §Perf A.4).
            def gathered(p, d: ParamDef):
                if "exp" in d.dims:     # expert weights stay fully sharded
                    return p
                dims = tuple(None if x == "fsdp" else x for x in d.dims)
                s = plan.spec(dims, d.shape)
                return jax.lax.with_sharding_constraint(p, s)

            params_g = jax.tree.map(gathered, params, pdefs, is_leaf=IS_DEF)
            micro = {k: _split_micro(v, M, 1 if k == "pos3" else 0)
                     for k, v in batch.items()}
            g0 = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), s)
                if any(e is not None for e in s)
                else jnp.zeros(p.shape, jnp.float32), params, grad_specs)

            def body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params_g, mb, cfg, plan)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / M, g_acc, grads)
                return (g_acc, loss_acc + loss / M,
                        aux_acc + metrics["aux"] / M), None

            (grads, loss, aux), _ = jax.lax.scan(
                body, (g0, jnp.float32(0.0), jnp.float32(0.0)), micro)
            grads = constrain_grads(grads)
            metrics = {"nll": loss, "aux": aux, "zloss": jnp.float32(0.0)}
        params, opt_state, info = adamw_update(params, grads, opt_state,
                                               opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **info}
    return train_step


def make_prefill_step(cfg: ArchConfig, plan, cache_len: int):
    def prefill_step(params, batch):
        return prefill(params, batch, cfg, plan, cache_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig, plan):
    def serve_step(params, cache, batch):
        new_cache, logits = decode_step(params, cache, batch["tokens"], cfg,
                                        plan)
        return new_cache, jnp.argmax(logits, axis=-1)
    return serve_step


def input_specs(arch: ArchConfig, shape: ShapeConfig, mesh,
                opt_cfg: OptConfig | None = None):
    """(step_fn, args-as-SDS) for one dry-run cell."""
    plan = plan_for_mesh(mesh)
    opt_cfg = opt_cfg or OptConfig(state_dtype=arch.opt_state_dtype)
    pdefs = param_defs(arch)
    params_sds = sds_tree(pdefs, mesh, plan)

    if shape.kind == "train":
        opt_sds = sds_tree(opt_state_defs(pdefs, opt_cfg), mesh, plan)
        batch_sds = sds_tree(batch_defs(arch, shape), mesh, plan)
        fn = make_train_step(arch, plan, opt_cfg)
        return fn, (params_sds, opt_sds, batch_sds)

    if shape.kind == "prefill":
        batch_sds = sds_tree(batch_defs(arch, shape), mesh, plan)
        fn = make_prefill_step(arch, plan, shape.seq_len)
        return fn, (params_sds, batch_sds)

    if shape.kind == "decode":
        cdefs = cache_defs(arch, shape.global_batch, shape.seq_len)
        cache_sds = sds_tree(cdefs, mesh, plan)
        batch_sds = sds_tree(batch_defs(arch, shape, decode=True), mesh, plan)
        fn = make_decode_step(arch, plan)
        return fn, (params_sds, cache_sds, batch_sds)

    raise ValueError(shape.kind)
