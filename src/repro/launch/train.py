"""Training launcher.

CPU-scale real run:            python -m repro.launch.train --arch qwen3-0.6b \
                                   --smoke --steps 200
Production lowering (dry-run): use repro.launch.dryrun.

``--smoke`` uses the reduced same-family config; otherwise the full assigned
config is used (feasible only on a real cluster; on CPU it will be slow/OOM).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_arch, plan_for_mesh, smoke_of
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.train import FailureInjector, OptConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        arch = smoke_of(arch)
    mesh = make_production_mesh() if args.production_mesh else \
        make_local_mesh()
    plan = plan_for_mesh(mesh)
    data = DataConfig(vocab_size=arch.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    tr = Trainer(
        arch, mesh, plan, data,
        OptConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                  total_steps=args.steps),
        TrainerConfig(num_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir),
        injector=FailureInjector(tuple(args.fail_at)) if args.fail_at
        else None)
    tr.run()
    for h in tr.history:
        print(json.dumps(h))
    print(f"# params={arch.n_params():,} restarts={tr.restarts}")


if __name__ == "__main__":
    main()
