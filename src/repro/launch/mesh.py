"""Mesh construction — the one place axis names are decided.

``MeshSpec`` is the axis-name contract (DESIGN.md §10) in code: every mesh
this repo builds — the coloring core's 1D ``workers`` mesh, the 2D
``batch × shard`` serving mesh, the LM stack's ``data``/``model`` meshes —
comes from a spec, so ``core.comm.shard_axis_of`` and the smoke tests
always agree on what each axis means.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the cross-pod (DCN/ICI-bridge) dimension; DP and FSDP extend over it.
Coloring:   (batch, workers) — graph partitions shard over ``workers``,
graph lanes of the batched pipeline shard over ``batch``.

Functions, not module constants: importing this module never touches JAX
device state (the dry-run must set XLA_FLAGS before first device init).
Mesh construction goes through ``repro.compat`` so the same code runs on
old jax (no ``AxisType``) and new.
"""
from __future__ import annotations

import dataclasses

import jax

from repro import compat
from repro.core.comm import AXIS, BATCH_AXIS


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Declarative mesh geometry: parallel ``shape`` / ``axes`` tuples.

    ``build()`` materializes the device mesh (touching JAX device state);
    the spec itself is hashable and cheap, so program-cache keys and
    configs can carry it.  The classmethods are the repo's canonical
    layouts — call sites should not invent axis names.
    """

    shape: tuple
    axes: tuple

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @classmethod
    def worker(cls, n_workers: int) -> "MeshSpec":
        """Flat 1-axis coloring mesh: every device is one graph shard."""
        return cls((n_workers,), (AXIS,))

    @classmethod
    def coloring(cls, n_workers: int, batch: int = 1) -> "MeshSpec":
        """2D ``batch × shard`` coloring mesh (``batch=1`` is bitwise the
        1-axis path per shard; batch>1 shards graph lanes of the batched
        pipeline over devices)."""
        return cls((batch, n_workers), (BATCH_AXIS, AXIS))

    @classmethod
    def production(cls, *, multi_pod: bool = False) -> "MeshSpec":
        if multi_pod:
            return cls((2, 16, 16), ("pod", "data", "model"))
        return cls((16, 16), ("data", "model"))

    @classmethod
    def local(cls) -> "MeshSpec":
        """Degenerate 1-device smoke mesh (both LM axes size 1)."""
        return cls((1, 1), ("data", "model"))

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n

    def build(self):
        return compat.make_mesh(self.shape, self.axes)


def make_production_mesh(*, multi_pod: bool = False):
    return MeshSpec.production(multi_pod=multi_pod).build()


def make_worker_mesh(n_workers: int | None = None):
    """Flat 1-axis mesh for the coloring core (uses every device)."""
    n = n_workers or len(jax.devices())
    return MeshSpec.worker(n).build()


def make_coloring_mesh(n_workers: int | None = None, batch: int = 1):
    """2D ``(batch, workers)`` coloring mesh; needs batch × workers devices.

    ``batch`` shards the batched pipeline's graph-lane axis
    (``color_many_sharded``); solo dispatches replicate over it.
    """
    n = n_workers or len(jax.devices()) // batch
    return MeshSpec.coloring(n, batch).build()


def make_local_mesh():
    """Degenerate mesh for CPU smoke tests (1 device, both axes size 1)."""
    return MeshSpec.local().build()


def engine_lanes(mesh, lanes: int) -> int:
    """Lane count a continuous-batching engine on ``mesh`` must allocate.

    The engine's lane axis is sharded over the mesh's ``batch`` axis
    (``run_sharded_many``), so the configured ``ServeConfig.lanes`` is
    rounded up to a multiple of the batch axis size; ``mesh=None`` (sim
    executor) and 1D meshes keep it as-is.
    """
    lanes = max(1, int(lanes))
    if mesh is None:
        return lanes
    from repro.core.comm import batch_axis_size
    b = batch_axis_size(mesh)
    return -(-lanes // b) * b
