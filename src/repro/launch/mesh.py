"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the cross-pod (DCN/ICI-bridge) dimension; DP and FSDP extend over it.

Functions, not module constants: importing this module never touches JAX
device state (the dry-run must set XLA_FLAGS before first device init).
Mesh construction goes through ``repro.compat`` so the same code runs on
old jax (no ``AxisType``) and new.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_worker_mesh(n_workers: int | None = None):
    """Flat 1-axis mesh for the coloring core (uses every device)."""
    n = n_workers or len(jax.devices())
    return compat.make_mesh((n,), ("workers",))


def make_local_mesh():
    """Degenerate mesh for CPU smoke tests (1 device, both axes size 1)."""
    return compat.make_mesh((1, 1), ("data", "model"))
