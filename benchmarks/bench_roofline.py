"""§Roofline: aggregate the dry-run JSONs into the per-cell roofline table."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DRYRUN_DIR = Path("experiments/dryrun_final")
_FALLBACK = Path("experiments/dryrun")


def run(fast: bool = True):
    d = DRYRUN_DIR if DRYRUN_DIR.exists() else _FALLBACK
    if not d.exists():
        emit("roofline/missing", 0.0, "run python -m repro.launch.dryrun --all")
        return []
    rows = []
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        r = rec["roofline"]
        rows.append(rec)
        mem = rec.get("memory_analysis", {}).get("total_per_device", 0)
        emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dom={r['bottleneck']};compute_s={r['compute_s']:.4f};"
             f"memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"useful_flops={rec.get('useful_flops_ratio', 0):.2f};"
             f"mem_per_dev_GB={mem/1e9:.2f}")
    return rows


if __name__ == "__main__":
    run()
