"""Fig. 5, 6, 7: distributed scaling — FSS vs +RC vs +aRC, and the effect of
multiple RC iterations, across processor counts (simulated SPMD lanes)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ColorConfig, RecolorConfig, arc_sim, color_graph_sim,
                        colors_from_views, compute_order, ordering,
                        partition_graph, recolor_iterations, recolor_sim,
                        selection)

from .common import emit, geomean, suite_real, suite_rmat


def fss(g, P, mc, superstep=512):
    """First Fit + Smallest Last + synchronous — the FSS baseline of [26]."""
    pg = partition_graph(g, P)
    order = compute_order(pg, ordering.SMALLEST_LAST)
    cfg = ColorConfig(max_colors=mc, superstep=superstep,
                      selection=selection.FIRST_FIT)
    t0 = time.time()
    view, stats = color_graph_sim(pg, order, cfg)
    return pg, np.asarray(view), stats, time.time() - t0


def fig5(fast: bool = True):
    """Real-world graphs: normalized colors+time vs P for FSS / +RC / +aRC."""
    graphs = suite_real(fast)
    Ps = (1, 2, 4, 8, 16) if fast else (1, 2, 4, 8, 16, 32, 64)
    base: dict = {}
    for gname, g in graphs.items():
        _, _, st1, t1 = fss(g, 1, 1024)
        base[gname] = (st1["n_colors"], max(t1, 1e-9))
    for P in Ps:
        rows = {"fss": [], "rc": [], "arc": []}
        times = {"fss": [], "rc": [], "arc": []}
        for gname, g in graphs.items():
            pg, view, st, t = fss(g, P, 1024)
            rows["fss"].append(st["n_colors"] / base[gname][0])
            times["fss"].append(t / base[gname][1])
            t0 = time.time()
            _, rst = recolor_sim(pg, view, "nd", RecolorConfig(max_colors=1024))
            rows["rc"].append(rst["n_colors"] / base[gname][0])
            times["rc"].append((t + time.time() - t0) / base[gname][1])
            t0 = time.time()
            _, ast = arc_sim(pg, view, "nd", RecolorConfig(max_colors=1024),
                             ColorConfig(max_colors=1024, superstep=512))
            rows["arc"].append(ast["n_colors"] / base[gname][0])
            times["arc"].append((t + time.time() - t0) / base[gname][1])
        for k in rows:
            emit(f"fig5/P{P}/{k.upper()}", 0.0,
                 f"norm_colors={geomean(rows[k]):.3f};"
                 f"norm_time={geomean(times[k]):.3f}")


def fig6(fast: bool = True):
    """RMAT graphs: FSS vs +RC vs +aRC colors per graph (conflict-heavy)."""
    graphs = suite_rmat(fast)
    Ps = (4, 16) if fast else (4, 16, 64)
    for gname, g in graphs.items():
        mc = 4096 if "bad" in gname else 1024
        for P in Ps:
            pg, view, st, t = fss(g, P, mc)
            _, rst = recolor_sim(pg, view, "nd", RecolorConfig(max_colors=mc))
            _, ast = arc_sim(pg, view, "nd", RecolorConfig(max_colors=mc),
                             ColorConfig(max_colors=mc, superstep=512))
            emit(f"fig6/{gname}/P{P}", t * 1e6,
                 f"FSS={st['n_colors']};RC={rst['n_colors']};"
                 f"aRC={ast['n_colors']};rounds={st['n_rounds']}")


def fig7(fast: bool = True):
    """Multiple RC iterations at scale vs sequential LF/SL references."""
    graphs = suite_real(fast)
    P = 16 if fast else 64
    iters = 10
    for gname, g in graphs.items():
        pg1 = partition_graph(g, 1)
        lf, _ = _seq(g, ordering.LARGEST_FIRST)
        sl, _ = _seq(g, ordering.SMALLEST_LAST)
        pg, view, st, _ = fss(g, P, 1024)
        _, hist = recolor_iterations(pg, view, iters,
                                     RecolorConfig(max_colors=1024),
                                     base_perm="nd")
        cs = [h["n_colors"] for h in hist]
        emit(f"fig7/{gname}/P{P}", 0.0,
             f"FSS={st['n_colors']};RC1={cs[0]};RC10={cs[-1]};"
             f"seqLF={lf};seqSL={sl}")


def _seq(g, kind):
    pg = partition_graph(g, 1)
    order = compute_order(pg, kind)
    view, stats = color_graph_sim(pg, order,
                                  ColorConfig(max_colors=1024,
                                              superstep=4096))
    return stats["n_colors"], view


def run(fast: bool = True):
    fig5(fast)
    fig6(fast)
    fig7(fast)


if __name__ == "__main__":
    run()
