"""Regenerate the EXPERIMENTS.md §Roofline table from dry-run JSONs."""
from __future__ import annotations

import json
import sys
from pathlib import Path


def table(dryrun_dir="experiments/dryrun_final", mesh="pod16x16"):
    rows = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("arch") == "coloring" or rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append((rec["arch"], rec["shape"], "—", "—", "—", "—", "—",
                         "—", "skipped: " + rec.get("reason", "")[:40]))
            continue
        if rec.get("status") != "ok":
            rows.append((rec["arch"], rec["shape"], "ERR", "", "", "", "", "",
                         rec.get("error", "")[:40]))
            continue
        r = rec["roofline"]
        mem = rec.get("memory_analysis", {}).get("total_per_device", 0) / 1e9
        rows.append((
            rec["arch"], rec["shape"],
            f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}",
            f"{r['collective_s']:.3f}", r["bottleneck"],
            f"{rec.get('useful_flops_ratio', 0):.2f}", f"{mem:.1f}",
            "",
        ))
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful | mem GB/dev | note |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for row in rows:
        out.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(out)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod16x16"
    d = sys.argv[2] if len(sys.argv) > 2 else "experiments/dryrun_final"
    print(table(d, mesh))
