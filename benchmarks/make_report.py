"""Bench/report tables from the checked-in JSON artifacts.

  python benchmarks/make_report.py bench [root]   — the README's bench
        summary table, regenerated from the BENCH_*.json files
  python benchmarks/make_report.py lint [target..] — repro-lint summary
        table (per-rule finding counts against the committed baseline)
  python benchmarks/make_report.py [mesh] [dir]   — the EXPERIMENTS.md
        §Roofline table from dry-run JSONs (legacy default)
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def _geomean(xs):
    import math
    xs = [max(float(x), 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / max(len(xs), 1))


def bench_table(root: str | Path = ".") -> str:
    """One-line headline per BENCH_*.json (markdown; README §Benchmarks)."""
    root = Path(root)
    rows = []

    def rec(name):
        p = root / f"BENCH_{name}.json"
        return json.loads(p.read_text()) if p.exists() else None

    r = rec("hotpath")
    if r:
        rows.append((
            "hotpath", f"{r['graph']} P={r['P']}",
            f"chunked-ELL recolor **{r['recolor']['speedup']:.1f}x** the "
            f"dense-occupancy path; tile-parallel supersteps "
            f"{r['speculative']['speedup']:.1f}x the scalar loop"))
    r = rec("comm")
    if r:
        top = max(r["sweep"], key=lambda s: s["P"])
        rows.append((
            "comm", f"{r['graph']} P={top['P']}",
            f"sparse ships **{top['bytes_reduction_color'] * 100:.0f}%** / "
            f"{top['bytes_reduction_recolor'] * 100:.0f}% fewer bytes "
            f"(color/recolor) than all-gather, identical colorings"))
    r = rec("d2")
    if r:
        grid = [s for s in r["sweep"] if s["graph"].startswith("grid")]
        if grid:
            top = max(grid, key=lambda s: s["bytes_reduction_color"])
            rows.append((
                "d2", f"{top['graph']} P={top['P']}",
                f"distance-2 over the two-hop halo: sparse ships "
                f"**{top['bytes_reduction_color'] * 100:.0f}%** fewer bytes "
                f"on structured meshes"))
    r = rec("pipeline")
    if r:
        sp = _geomean([s["speedup"] for s in r["sweep"]])
        wins = sum(s["rand_beats_ff"] for s in r["seeding"])
        ps = ",".join(str(p) for p in sorted({s["P"] for s in r["sweep"]}))
        rows.append((
            "pipeline", f"K={r['n_iters']}, P∈{{{ps}}}",
            f"fused loop **{sp:.1f}x** (geomean) over the host loop, "
            f"bitwise-identical colorings; RAND seeding beats FF after "
            f"recoloring in {wins}/{len(r['seeding'])} cells"))
    r = rec("serve")
    if r:
        rows.append((
            "serve", f"{r['n_graphs']}-graph RMAT mix P={r['P']}",
            f"cost-model serving **{r['speedup']:.1f}x** "
            f"({r['graphs_per_s_batched']:.1f} vs "
            f"{r['graphs_per_s_seq']:.1f} graphs/s) over sequential "
            f"per-graph dispatch on fresh traffic; warm same-program "
            f"**{r['warm_speedup']:.2f}x**, program-cache hit rate "
            f"{r['program_cache']['hit_rate']:.2f}, warm p50/p99 "
            f"{r['warm_p50_ms']:.0f}/{r['warm_p99_ms']:.0f} ms"))

    r = rec("weak")
    if r:
        top = max(r["sweep"], key=lambda s: s["P"]) if r["sweep"] else None
        dr = max(r["dryrun2d"], key=lambda s: s["P"]) if r["dryrun2d"] else None
        pr = max(r["projections"], key=lambda s: s["scale"])
        parts = []
        if top:
            parts.append(
                f"scale-{top['scale']} @ P={top['P']} measured, sparse ships "
                f"**{top['bytes_reduction'] * 100:.0f}%** fewer bytes")
        if dr:
            axes = "×".join(f"{n}={s}" for n, s in dr["mesh"])
            parts.append(f"2D mesh ({axes}) lowers in {dr['compile_s']:.0f}s")
        parts.append(
            f"scale-{pr['scale']} int64 projection "
            f"{'fits' if pr['fits_hbm'] else 'exceeds'} HBM "
            f"({pr['total_per_shard'] / 1e9:.1f} GB/shard @ P={pr['P']})")
        setting = (f"n/P=2^14, P≤{top['P']}" if top
                   else f"dryrun P={dr['P']}" if dr else "projections")
        rows.append(("weak", setting, "; ".join(parts)))

    out = ["| bench | setting | headline |", "|---|---|---|"]
    out += [f"| {a} | {b} | {c} |" for a, b, c in rows]
    return "\n".join(out)


def table(dryrun_dir="experiments/dryrun_final", mesh="pod16x16"):
    rows = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("arch") == "coloring" or rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append((rec["arch"], rec["shape"], "—", "—", "—", "—", "—",
                         "—", "skipped: " + rec.get("reason", "")[:40]))
            continue
        if rec.get("status") != "ok":
            rows.append((rec["arch"], rec["shape"], "ERR", "", "", "", "", "",
                         rec.get("error", "")[:40]))
            continue
        r = rec["roofline"]
        mem = rec.get("memory_analysis", {}).get("total_per_device", 0) / 1e9
        rows.append((
            rec["arch"], rec["shape"],
            f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}",
            f"{r['collective_s']:.3f}", r["bottleneck"],
            f"{rec.get('useful_flops_ratio', 0):.2f}", f"{mem:.1f}",
            "",
        ))
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful | mem GB/dev | note |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for row in rows:
        out.append("| " + " | ".join(str(x) for x in row) + " |")
    return "\n".join(out)


def lint_table(targets=("src",), root: str | Path = ".") -> str:
    """Markdown summary of a repro-lint run (DESIGN.md §9) over ``targets``."""
    repo = Path(root).resolve()
    sys.path.insert(0, str(repo / "src"))
    from repro.analysis import RULES, run_lint
    res = run_lint(list(targets), root=repo,
                   baseline=repo / "tools" / "repro_lint_baseline.json")
    counts = res.counts()
    out = ["| rule | new findings |", "|---|---|"]
    out += [f"| `{rid}` | {counts.get(rid, 0)} |" for rid in sorted(RULES)]
    out.append(
        f"\n{res.n_files} file(s), {len(res.findings)} new, "
        f"{len(res.baselined)} baselined, {res.suppressed} suppression(s), "
        f"{len(res.errors)} error(s) — {'OK' if res.ok else 'FAIL'}")
    return "\n".join(out)


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "lint":
        print(lint_table(tuple(sys.argv[2:]) or ("src",)))
    elif len(sys.argv) > 1 and sys.argv[1] == "bench":
        print(bench_table(sys.argv[2] if len(sys.argv) > 2 else "."))
    else:
        mesh = sys.argv[1] if len(sys.argv) > 1 else "pod16x16"
        d = sys.argv[2] if len(sys.argv) > 2 else "experiments/dryrun_final"
        print(table(d, mesh))
