"""Exchange-scheme scaling: sparse neighbour-to-neighbour vs all-gather.

Sweeps simulated P = 2..16 on the RMAT bench graph and records, per scheme
and per driver (speculative coloring + one ND recoloring iteration):

  - wall time (sim backend — compute cost of the exchange structure),
  - *measured* wire bytes from the drivers' comm accumulator
    (`stats["wire_bytes"]`, the bytes an executed exchange actually ships),
  - modeled bytes per full exchange from the static plan,
  - a coloring hash per scheme — the two schemes must agree bitwise.

Writes BENCH_comm.json so the comm-volume trajectory is recorded across
PRs.  The all-gather table grows O(P·max_b) per exchange while the sparse
schedule tracks the realized cross-edge structure; the gap is the paper's
"communication scheme that scales gracefully" (DESIGN.md §2).
"""
from __future__ import annotations

import hashlib
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (ColorConfig, RecolorConfig, color_graph_sim,
                        colors_from_views, compute_order, ordering,
                        partition_graph, recolor_sim, rmat)
from repro.core.comm import allgather_bytes_per_exchange

from .common import emit

MC = 512
REPEAT = 3
P_SWEEP = (2, 4, 8, 16)


def _hash(colors: np.ndarray) -> str:
    return hashlib.sha256(colors.astype(np.int32).tobytes()).hexdigest()[:16]


def _timeit(fn):
    jax.block_until_ready(fn()[0])            # warmup / compile
    t0 = time.time()
    for _ in range(REPEAT):
        out = fn()
        jax.block_until_ready(out[0])
    return out, (time.time() - t0) / REPEAT


def run(fast: bool = True, out_path: str | Path = "BENCH_comm.json"):
    scale = 12 if fast else 14
    g = rmat.rmat_good(scale, 8, seed=1)
    rec: dict = dict(graph=f"rmat_good_s{scale}", n=g.n, m=g.m,
                     max_colors=MC, repeat=REPEAT, sweep=[])

    for P in P_SWEEP:
        pg = partition_graph(g, P)
        plan = pg.comm_plan
        order = compute_order(pg, ordering.INTERNAL_FIRST)
        row: dict = dict(
            P=P,
            n_rounds=len(plan.shifts),
            max_boundary=int(pg.max_boundary),
            max_send=int(plan.max_send),
            modeled_sparse_bytes_per_ex=plan.bytes_per_exchange(),
            modeled_allgather_bytes_per_ex=allgather_bytes_per_exchange(
                P, int(pg.max_boundary)),
        )
        hashes = {}
        for scheme in ("allgather", "sparse"):
            cfg = ColorConfig(max_colors=MC, superstep=512, seed=0,
                              scheme=scheme)
            (view, st), t = _timeit(lambda: color_graph_sim(pg, order, cfg))
            hashes[scheme] = _hash(colors_from_views(pg, np.asarray(view)))
            row[f"color_{scheme}_s"] = t
            row[f"color_{scheme}_wire_bytes"] = st["wire_bytes"]
            rcfg = RecolorConfig(max_colors=MC, scheme=scheme)
            key = jax.random.key(7)
            (v2, st2), t2 = _timeit(
                lambda: recolor_sim(pg, view, "nd", rcfg, key=key))
            row[f"recolor_{scheme}_s"] = t2
            row[f"recolor_{scheme}_wire_bytes"] = st2["wire_bytes"]
        row["colorings_identical"] = hashes["sparse"] == hashes["allgather"]
        row["color_hash"] = hashes["sparse"]
        row["color_speedup"] = row["color_allgather_s"] / row["color_sparse_s"]
        row["recolor_speedup"] = (row["recolor_allgather_s"]
                                  / row["recolor_sparse_s"])
        row["bytes_reduction_color"] = 1.0 - (
            row["color_sparse_wire_bytes"]
            / max(row["color_allgather_wire_bytes"], 1))
        row["bytes_reduction_recolor"] = 1.0 - (
            row["recolor_sparse_wire_bytes"]
            / max(row["recolor_allgather_wire_bytes"], 1))
        rec["sweep"].append(row)
        emit(f"comm/P{P}/color_sparse", row["color_sparse_s"] * 1e6,
             f"bytes={row['color_sparse_wire_bytes']};"
             f"red={row['bytes_reduction_color']:.2f};"
             f"identical={row['colorings_identical']}")
        emit(f"comm/P{P}/recolor_sparse", row["recolor_sparse_s"] * 1e6,
             f"bytes={row['recolor_sparse_wire_bytes']};"
             f"red={row['bytes_reduction_recolor']:.2f}")

    Path(out_path).write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    run()
