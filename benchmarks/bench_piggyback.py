"""Fig. 4: piggybacking — message counts (paper's metric) + recoloring
runtime with coalesced vs per-step exchanges (the TPU realization)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ColorConfig, RecolorConfig, color_graph_sim,
                        colors_from_views, compute_order, message_stats,
                        ordering, partition_graph, recolor_sim)
from repro.core.recolor import permutation_rank

from .common import emit, suite_real, suite_rmat


def run(fast: bool = True, P: int = 32):
    graphs = {**suite_real(fast), **suite_rmat(fast)}
    for gname, g in graphs.items():
        mc = 1024 if g.max_degree < 1000 else 4096
        pg = partition_graph(g, P)
        order = compute_order(pg, ordering.INTERNAL_FIRST)
        view, _ = color_graph_sim(pg, order, ColorConfig(max_colors=mc,
                                                         superstep=512))
        colors = colors_from_views(pg, np.asarray(view))
        sizes = np.bincount(colors, minlength=mc).astype(np.int32)
        sizes[0] = 0
        rank = np.asarray(permutation_rank(jnp.asarray(sizes), "nd",
                                           jax.random.key(0)))
        ms = message_stats(pg, colors, rank)

        # runtime: one RC iteration, piggyback on/off
        key = jax.random.key(1)
        _, t_pig = _time_rc(pg, view, mc, True, key)
        _, t_all = _time_rc(pg, view, mc, False, key)
        emit(f"fig4/{gname}", t_pig * 1e6,
             f"msgs_base={ms.base_total};msgs_nonempty={ms.base_nonempty};"
             f"msgs_pig={ms.pig_total};msg_reduction={ms.message_reduction:.2f};"
             f"collectives_base={ms.collective_steps_base};"
             f"collectives_pig={ms.collective_steps_pig};"
             f"t_pig_s={t_pig:.3f};t_per_step_s={t_all:.3f}")


def _time_rc(pg, view, mc, piggyback, key):
    cfg = RecolorConfig(max_colors=mc, piggyback=piggyback)
    out, _ = recolor_sim(pg, np.asarray(view), "nd", cfg, key=key)  # compile
    t0 = time.time()
    out, stats = recolor_sim(pg, np.asarray(view), "nd", cfg, key=key)
    return stats, time.time() - t0


if __name__ == "__main__":
    run()
