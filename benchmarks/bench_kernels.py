"""Color-selection kernel benchmarks: jnp oracle timing (the CPU-executable
path) + Pallas interpret-mode validation sweep. On real TPU hardware the
pallas_call path replaces the oracle; interpret mode here only proves
correctness, its wall time is not meaningful."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit


def run(fast: bool = True):
    rng = np.random.default_rng(0)
    shapes = [(4096, 32, 256), (16384, 16, 512)] if fast else \
        [(4096, 32, 256), (16384, 16, 512), (65536, 32, 1024)]
    for (v, d, mc) in shapes:
        nbr = rng.integers(0, mc, (v, d)).astype(np.int32)
        active = np.ones(v, bool)
        rand = rng.integers(0, 2**32, v, dtype=np.uint32)

        ff = jax.jit(lambda n, a: ref.first_fit(n, a, mc))
        ff(jnp.asarray(nbr), jnp.asarray(active)).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            ff(jnp.asarray(nbr), jnp.asarray(active)).block_until_ready()
        t_ref = (time.time() - t0) / 5

        # pallas interpret: correctness only
        out_k = ops.color_select(nbr, active, rand, max_colors=mc, x=0)
        out_r = ff(jnp.asarray(nbr), jnp.asarray(active))
        match = bool((np.asarray(out_k) == np.asarray(out_r)).all())
        emit(f"kernel/first_fit/v{v}_d{d}_mc{mc}", t_ref * 1e6,
             f"oracle_us={t_ref*1e6:.0f};pallas_interpret_match={match};"
             f"throughput_Mvtx_s={v/t_ref/1e6:.1f}")

        rx = jax.jit(lambda n, a, r: ref.random_x(n, a, r, 10, mc))
        rx(jnp.asarray(nbr), jnp.asarray(active),
           jnp.asarray(rand)).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            rx(jnp.asarray(nbr), jnp.asarray(active),
               jnp.asarray(rand)).block_until_ready()
        t_ref = (time.time() - t0) / 5
        out_k = ops.color_select(nbr, active, rand, max_colors=mc, x=10)
        out_r = rx(jnp.asarray(nbr), jnp.asarray(active), jnp.asarray(rand))
        match = bool((np.asarray(out_k) == np.asarray(out_r)).all())
        emit(f"kernel/random_10/v{v}_d{d}_mc{mc}", t_ref * 1e6,
             f"oracle_us={t_ref*1e6:.0f};pallas_interpret_match={match}")


if __name__ == "__main__":
    run()
