"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  table12   Tables 1/2: graph suite properties + sequential NAT/LF/SL
  fig2/3    sequential recoloring: orderings x permutations, randomness
  fig4      piggybacking: message counts + coalesced-exchange runtime
  fig5/6/7  distributed scaling: FSS vs +RC vs +aRC, multi-iteration RC
  fig8910   Random-X Fit time-quality trade-off, "speed"/"quality" presets
  kernel    color-selection kernels (oracle timing + pallas validation)
  hotpath   legacy scalar/dense vs ELL/bitset hot paths (BENCH_hotpath.json)
  comm      sparse vs all-gather exchange P-scaling sweep (BENCH_comm.json)
  d2        distance-2 coloring over the two-hop halo (BENCH_d2.json)
  pipeline  fused device-resident color->recolor loop vs the host loop
            (BENCH_pipeline.json)
  serve     batched multi-graph dispatch vs sequential per-graph dispatch
            on a fresh-traffic RMAT mix (BENCH_serve.json)
  roofline  per-(arch x shape x mesh) roofline terms from the dry-run
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graphs (slow); default is fast mode")
    ap.add_argument("--only", default=None,
                    help="comma list: tables,seq,piggyback,dist,randomx,"
                         "kernels,hotpath,comm,d2,pipeline,serve,roofline")
    args = ap.parse_args()
    fast = not args.full
    from benchmarks import (bench_comm, bench_d2, bench_distributed,
                            bench_hotpath, bench_kernels, bench_piggyback,
                            bench_pipeline, bench_randomx, bench_roofline,
                            bench_seq_recolor, bench_serve, bench_tables)
    mods = dict(tables=bench_tables, seq=bench_seq_recolor,
                piggyback=bench_piggyback, dist=bench_distributed,
                randomx=bench_randomx, kernels=bench_kernels,
                hotpath=bench_hotpath, comm=bench_comm, d2=bench_d2,
                pipeline=bench_pipeline, serve=bench_serve,
                roofline=bench_roofline)
    chosen = (args.only.split(",") if args.only else list(mods))
    print("name,us_per_call,derived")
    for name in chosen:
        t0 = time.time()
        mods[name].run(fast=fast)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
