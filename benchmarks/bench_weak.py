import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Weak scaling: fixed vertices-per-shard, growing shard count.

The two lines above MUST stay first: the 2D-mesh dryrun cells need 512
placeholder host devices and JAX locks the device count on first init.

Three layers, one JSON (BENCH_weak.json):

1. *Measured* sweep — ``pipeline_sim`` on RMAT graphs with n/P held at
   2**14 (scale 16 @ P=4 ... scale 20 @ P=64), recording wall time and
   the comm accumulator's wire bytes against the static plan's modeled
   sparse and all-gather bytes per exchange (DESIGN.md §2).  Weak scaling
   holds per-shard work constant, so the byte curves isolate how each
   exchange scheme's volume grows with P.
2. *Lowered* cells — the batched pipeline compiled (not run) on real 2D
   ``batch × shard`` meshes at P=256 (``(2, 256)``) and P=512
   (``(1, 512)``), proving the weak-scaling serving layout lowers with
   the expected collective structure (DESIGN.md §10).  These cells keep
   n/P at 2**11: lowering exercises program structure, not data scale,
   and a scale-22 host-side partition would dominate CI time.
3. *Projected* cells — ``roofline.coloring_memory_projection`` for the
   int64-id regime (RMAT scale 31-36, P up to 32768): per-shard bytes,
   the id/ELL dtypes ``graph.id_policy`` picks, and whether a shard fits
   HBM.  No allocation; this is the giant-graph envelope the id-width
   policy exists for.

``--dryrun-only`` (CI's weak-dryrun job) runs layer 2's P=256 cell and
layer 3 only.
"""
import argparse
import json
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import roofline
from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                        compute_order, ordering, partition_graph,
                        pipeline_sim, rmat)
from repro.core.comm import (allgather_bytes_per_exchange, batch_axis_of,
                             mesh_axes, run_sharded_many, shard_axis_of)
from repro.core.pipeline import color_then_recolor
from repro.launch.mesh import make_coloring_mesh
from repro.roofline import analyze_hlo

from .common import emit

MC = 256
N_ITERS = 2
# (rmat scale, P): n/P fixed at 2**14 (full) / 2**12 (fast)
SWEEP_FULL = ((16, 4), (17, 8), (18, 16), (19, 32), (20, 64))
SWEEP_FAST = ((14, 4), (15, 8), (16, 16))
# lowered 2D-mesh cells: (scale, P, batch) with n/P = 2**11
DRYRUN_FULL = ((19, 256, 2), (20, 512, 1))
DRYRUN_FAST = ((19, 256, 2),)
# projected int64-regime cells: (scale, P) — the first three keep
# n/P = 2**21 (per-shard bytes constant under weak scaling); the
# scale-36 @ P=2048 cell over-fills HBM on purpose (fits_hbm=False)
PROJECTIONS = ((31, 1024), (33, 4096), (36, 32768), (36, 2048))


def _cfg(scheme: str) -> PipelineConfig:
    return PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=512, scheme=scheme),
        recolor=RecolorConfig(max_colors=MC, scheme=scheme),
        n_iters=N_ITERS, patience=0)


def _measured_row(scale: int, P: int) -> dict:
    g = rmat.rmat_good(scale, 8, seed=1)
    pg = partition_graph(g, P)
    plan = pg.comm_plan
    order = compute_order(pg, ordering.INTERNAL_FIRST)
    row: dict = dict(
        scale=scale, P=P, n=g.n, m=g.m,
        n_per_shard=g.n // P,
        n_local_max=int(pg.n_local_max),
        max_boundary=int(pg.max_boundary),
        n_rounds=len(plan.shifts),
        modeled_sparse_bytes_per_ex=plan.bytes_per_exchange(),
        modeled_allgather_bytes_per_ex=allgather_bytes_per_exchange(
            P, int(pg.max_boundary)),
    )
    for scheme in ("sparse", "allgather"):
        t0 = time.time()
        view, res = pipeline_sim(pg, order, _cfg(scheme))
        jax.block_until_ready(view)
        # measured bytes: initial coloring + every recoloring iteration
        wire = res["color"]["wire_bytes"] + sum(
            h["wire_bytes"] for h in res["history"])
        row[f"{scheme}_wall_s"] = round(time.time() - t0, 3)
        row[f"{scheme}_wire_bytes"] = int(wire)
        row[f"{scheme}_colors"] = res["history"][-1]["n_colors"]
    row["bytes_reduction"] = 1.0 - (row["sparse_wire_bytes"]
                                    / max(row["allgather_wire_bytes"], 1))
    return row


def _dryrun_row(scale: int, P: int, batch: int) -> dict:
    """Lower + compile the batched pipeline on a 2D mesh; no execution."""
    g = rmat.rmat_er(scale, 8, seed=1)
    pg = partition_graph(g, P)
    mesh = make_coloring_mesh(P, batch=batch)
    axis = shard_axis_of(mesh)
    B = max(2, batch)                          # lanes (a multiple of batch)
    arrs = {k: jnp.repeat(jnp.asarray(v)[:, None], B, axis=1)
            for k, v in pg.arrays().items()}
    order = jnp.zeros((P, B, pg.n_local_max), jnp.int32)
    keys = jax.random.split(jax.random.key(0), B)
    cfg = _cfg("allgather")
    fn = jax.vmap(partial(color_then_recolor, cfg=cfg, P_size=P, axis=axis,
                          lane_axes=(batch_axis_of(mesh),)))
    t0 = time.time()
    compiled = jax.jit(
        lambda a, o, k1, k2: run_sharded_many(fn, mesh, (a, o), (k1, k2),
                                              axis=axis)).lower(
            arrs, order, keys, keys).compile()
    analysis = analyze_hlo(compiled.as_text())
    return dict(
        scale=scale, P=P, n=g.n, n_per_shard=g.n // P,
        mesh=[[n, s] for n, s in mesh_axes(mesh)], batch_lanes=B,
        compile_s=round(time.time() - t0, 2),
        coll_count=analysis["coll_count"],
        coll_bytes=analysis["coll_bytes"],
    )


def _projection_row(scale: int, P: int) -> dict:
    proj = roofline.coloring_memory_projection(2**scale, P, maxd=64)
    return dict(scale=scale, **proj)


def run(fast: bool = True, out_path: str | Path = "BENCH_weak.json",
        dryrun_only: bool = False):
    rec: dict = dict(max_colors=MC, n_iters=N_ITERS,
                     sweep=[], dryrun2d=[], projections=[])

    if not dryrun_only:
        for scale, P in (SWEEP_FAST if fast else SWEEP_FULL):
            row = _measured_row(scale, P)
            rec["sweep"].append(row)
            emit(f"weak/s{scale}_P{P}/sparse", row["sparse_wall_s"] * 1e6,
                 f"wire={row['sparse_wire_bytes']};"
                 f"model={row['modeled_sparse_bytes_per_ex']};"
                 f"red={row['bytes_reduction']:.2f}")

    for scale, P, batch in (DRYRUN_FAST if fast else DRYRUN_FULL):
        row = _dryrun_row(scale, P, batch)
        rec["dryrun2d"].append(row)
        emit(f"weak/dryrun_s{scale}_P{P}", row["compile_s"] * 1e6,
             f"mesh={row['mesh']};colls={row['coll_count']}")

    for scale, P in PROJECTIONS:
        row = _projection_row(scale, P)
        rec["projections"].append(row)
        emit(f"weak/proj_s{scale}_P{P}", 0.0,
             f"id={row['id_dtype']};per_shard={row['total_per_shard']};"
             f"fits_hbm={row['fits_hbm']}")

    Path(out_path).write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--dryrun-only", action="store_true")
    ap.add_argument("--out", default="BENCH_weak.json")
    args = ap.parse_args()
    run(fast=args.fast, out_path=args.out, dryrun_only=args.dryrun_only)
