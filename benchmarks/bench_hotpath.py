"""Old scalar/dense hot paths vs the ELL/bitset rework (sim backend).

Measures, on the RMAT bench graph (rmat_good; scale 12 fast / 14 full):

  recolor      — the seed dense-occupancy step loop (kept here as a local
                 legacy reference; it scatters the whole edge list into an
                 O(V * max_colors) boolean matrix every color step) vs the
                 chunked ELL + bitset `recolor_sim` hot path.
  speculative  — sequential scalar supersteps (`parallel_chunk=False`, the
                 paper-faithful mode) vs tile-parallel supersteps.

Emits CSV rows and writes BENCH_hotpath.json (vertices-colored-per-second)
so the perf trajectory is recorded across PRs.
"""
from __future__ import annotations

import json
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ColorConfig, RecolorConfig, color_graph_sim,
                        colors_from_views, compute_order, ordering,
                        partition_graph, recolor_sim, rmat)
from repro.core.comm import AxisComm, exchange_boundary, run_sim
from repro.core.recolor import (_needed_exchanges, class_sizes,
                                permutation_rank)

from .common import emit

P = 4
MC = 512
REPEAT = 5


def _recolor_spmd_legacy(arrs, view, key, perm_kind, cfg: RecolorConfig):
    """The seed recolor step loop: dense occupancy scatter + argmin."""
    comm = AxisComm()
    n_local_max = arrs["indptr"].shape[0] - 1
    n_slots = arrs["prio"].shape[0]
    mc = cfg.max_colors

    sizes, _ = class_sizes(view, arrs["n_local"], n_local_max, mc, comm)
    n_classes = jnp.sum(sizes > 0).astype(jnp.int32)
    rank = permutation_rank(sizes, perm_kind, key)
    step_of = rank[view].at[n_slots - 1].set(0)
    needed = _needed_exchanges(step_of, arrs, n_local_max, n_classes, mc,
                               comm, cfg.piggyback)
    exchange = partial(exchange_boundary, boundary=arrs["boundary"],
                       ghost_owner=arrs["ghost_owner"],
                       ghost_slot=arrs["ghost_slot"],
                       n_local_max=n_local_max, comm=comm)
    src, dst = arrs["edge_src"], arrs["indices"]
    valid_local = jnp.arange(n_local_max) < arrs["n_local"]

    def step_body(t, carry):
        new_view, n_ex = carry
        occ = jnp.zeros((n_local_max + 1, mc), bool).at[
            src, new_view[dst]].max(True)
        occ = occ[:n_local_max].at[:, 0].set(True)
        first_free = jnp.argmin(occ, axis=1).astype(jnp.int32)
        active = (step_of[:n_local_max] == t) & valid_local
        new_local = jnp.where(active, first_free, new_view[:n_local_max])
        new_view = jax.lax.dynamic_update_slice(
            new_view, new_local.astype(new_view.dtype), (0,))
        do_ex = needed[jnp.minimum(t, mc)] | (t == n_classes)
        new_view = jax.lax.cond(do_ex, exchange, lambda v: v, new_view)
        return new_view, n_ex + do_ex.astype(jnp.int32)

    new_view, _ = jax.lax.fori_loop(
        1, n_classes + 1, step_body,
        (jnp.zeros((n_slots,), jnp.int32), jnp.int32(0)))
    return new_view


def _timeit(fn, *args):
    jax.block_until_ready(fn(*args))          # warmup / compile
    t0 = time.time()
    for _ in range(REPEAT):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.time() - t0) / REPEAT


def run(fast: bool = True, out_path: str | Path = "BENCH_hotpath.json"):
    scale = 12 if fast else 14
    g = rmat.rmat_good(scale, 8, seed=1)
    pg = partition_graph(g, P)
    order = compute_order(pg, ordering.NATURAL)
    rec: dict = dict(graph=f"rmat_good_s{scale}", n=g.n, m=g.m, P=P,
                     max_colors=MC, repeat=REPEAT)

    # --- speculative: sequential scalar vs tile-parallel supersteps --------
    seq_cfg = ColorConfig(max_colors=MC, superstep=512, parallel_chunk=False)
    par_cfg = ColorConfig(max_colors=MC, superstep=512, parallel_chunk=True)
    view_seq, t_seq = _timeit(lambda: color_graph_sim(pg, order, seq_cfg)[0])
    view_par, t_par = _timeit(lambda: color_graph_sim(pg, order, par_cfg)[0])
    rec["speculative"] = dict(
        sequential_s=t_seq, parallel_s=t_par, speedup=t_seq / t_par,
        sequential_vps=g.n / t_seq, parallel_vps=g.n / t_par,
        n_colors_sequential=int(colors_from_views(pg, np.asarray(view_seq)).max()),
        n_colors_parallel=int(colors_from_views(pg, np.asarray(view_par)).max()),
    )
    emit("hotpath/speculative/sequential", t_seq * 1e6,
         f"vps={g.n/t_seq:,.0f}")
    emit("hotpath/speculative/parallel", t_par * 1e6,
         f"vps={g.n/t_par:,.0f};speedup={t_seq/t_par:.2f}x")

    # --- recolor: legacy dense occupancy vs chunked ELL bitset -------------
    rcfg = RecolorConfig(max_colors=MC)
    key = jax.random.key(7)
    arrs = {k: jnp.asarray(v) for k, v in pg.arrays().items()}
    legacy = jax.jit(lambda a, v, k: run_sim(
        partial(_recolor_spmd_legacy, perm_kind="nd", cfg=rcfg),
        P, (a, v), (k,)))
    v_leg, t_leg = _timeit(lambda: legacy(arrs, jnp.asarray(view_seq), key))
    v_new, t_new = _timeit(
        lambda: recolor_sim(pg, view_seq, "nd", rcfg, key=key)[0])
    same = bool((colors_from_views(pg, np.asarray(v_leg))
                 == colors_from_views(pg, np.asarray(v_new))).all())
    rec["recolor"] = dict(
        legacy_s=t_leg, ell_s=t_new, speedup=t_leg / t_new,
        legacy_vps=g.n / t_leg, ell_vps=g.n / t_new,
        colorings_identical=same,
    )
    emit("hotpath/recolor/legacy_dense", t_leg * 1e6, f"vps={g.n/t_leg:,.0f}")
    emit("hotpath/recolor/ell_bitset", t_new * 1e6,
         f"vps={g.n/t_new:,.0f};speedup={t_leg/t_new:.2f}x;identical={same}")

    Path(out_path).write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    run()
