"""Tables 1 & 2: graph properties + sequential NAT/LF/SL colors and time."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ColorConfig, color_graph_sim, colors_from_views,
                        compute_order, ordering, partition_graph)

from .common import emit, suite_real, suite_rmat


def seq_colors(g, kind: str, max_colors: int = 1024):
    pg = partition_graph(g, 1)
    order = compute_order(pg, kind)
    cfg = ColorConfig(max_colors=max_colors, superstep=4096)
    t0 = time.time()
    view, stats = color_graph_sim(pg, order, cfg)
    dt = time.time() - t0
    return stats["n_colors"], dt


def run(fast: bool = True):
    rows = []
    for name, g in {**suite_real(fast), **suite_rmat(fast)}.items():
        mc = 1024 if g.max_degree < 1000 else 4096
        nat, t_nat = seq_colors(g, ordering.NATURAL, mc)
        lf, _ = seq_colors(g, ordering.LARGEST_FIRST, mc)
        sl, _ = seq_colors(g, ordering.SMALLEST_LAST, mc)
        rows.append((name, g.n, g.m, g.max_degree, nat, lf, sl, t_nat))
        emit(f"table12/{name}", t_nat * 1e6,
             f"V={g.n};E={g.m};maxdeg={g.max_degree};NAT={nat};LF={lf};SL={sl}")
        # the paper's qualitative claim: SL <= LF <= NAT (usually)
    return rows


if __name__ == "__main__":
    run()
