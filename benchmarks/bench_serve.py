"""Cost-model serving vs a sequential per-graph loop (ISSUE 5 + 6).

The serving workload: a *stream* of small-to-medium conflict graphs, each
needing the fused color->recolor pipeline.  Real traffic keeps producing
fresh graphs, and a fresh graph is a fresh XLA program under per-graph
dispatch — its padded shapes (``maxd``, ``m_local_max``, ghost/boundary
widths) are data-dependent, so the jit cache never converges.  The
``ColoringService`` collapses that: pow2 shape buckets, pow2 batch lanes,
pow2-rung-quantized sparse comm plans and the ``PlanSignature``-keyed
program cache make the program set finite, and the per-request cost model
routes each request by a cache probe — compiled program → immediate solo
dispatch, miss → shared batch-lane compile (DESIGN.md §2/§8).

Protocol (both paths see the same traffic; request-id-folded RNG keys make
their colorings identical, asserted):

  - wave 0 is cold on both sides (compiles included in ``warmup_*_s``),
    then ``prewarm`` compiles the service's one-lane programs;
  - wave 1 is **fresh traffic**: sequential = one ``pipeline_sim`` per
    graph — new data-dependent shapes, new compiles; service = cost-model
    routing, where wave-0 signatures hit and dispatch solo and new
    signatures share batch-lane compiles.  ``speedup`` is this leg;
  - the **warm leg** resubmits wave 1 verbatim after a second prewarm:
    every request takes the solo hit path (program compiled, partition
    memoized), against the sequential loop re-run with its jit cache warm
    (interleaved min-of-N).  ``warm_speedup`` is the cost-model fix for
    the pre-cost-model 0.62x regression: warm same-program traffic must
    never lose to sequential dispatch (>= 1.0x).

Reports p50/p99 per-request latency (from the service's per-dispatch
wall times) and the program-cache hit rate alongside throughput.

Acceptance (ISSUE 6): warm_speedup >= 1.0x, fresh-traffic speedup within
10% of the pre-cost-model batched number.  The ``open_loop`` section
sweeps Poisson arrivals (light traffic + rare ~50x stragglers) at
0.5x/1x/2x load through the continuous-batching lane engine vs the
flush-when-idle server on a hybrid clock (scripted virtual arrivals,
measured wall seconds per scheduler step) — continuous must beat the
flush server's p99 at >= 2 of the 3 rates.  Writes BENCH_serve.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                        assert_valid, bucket_graphs, compute_order,
                        ordering, partition_graph, pipeline_sim,
                        program_cache_stats, rmat)
from repro.launch.serve_coloring import ColoringService, FakeClock, ServeConfig

from .common import emit

MC = 512
P = 4
N_GRAPHS = 64
REPEAT = 3          # warm legs only: min-of-REPEAT, interleaved


def _wave(fast: bool, seed: int):
    """A fresh 64-graph RMAT request wave (three classes, mixed scales)."""
    lo, hi = (6, 8) if fast else (8, 10)
    rng = np.random.default_rng(seed)
    gens = (rmat.rmat_er, rmat.rmat_good, rmat.rmat_bad)
    return [gens[i % 3](int(rng.integers(lo, hi + 1)), 8,
                        seed=int(rng.integers(1 << 30)))
            for i in range(N_GRAPHS)]


def _pcts(lats):
    lats = sorted(lats)
    return (lats[len(lats) // 2] * 1e3,
            lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e3)


def _drive_open_loop(svc, arrivals):
    """Open-loop scripted arrivals on a hybrid clock: arrival times are
    virtual (``FakeClock``), each scheduler call advances the clock by its
    *measured* wall seconds — so latency percentiles are load-dependent
    while the arrival process stays exactly reproducible.  Flush mode
    drains the whole queue per call (flush-when-idle server); continuous
    mode runs one ``poll`` per call.  Returns per-request latencies
    (completion virtual time − scripted arrival time)."""
    clock = svc._clock
    assert isinstance(clock, FakeClock)
    pend = sorted(arrivals, key=lambda a: a[0])
    arrive_t, lats, i = {}, [], 0
    while i < len(pend) or svc.pending:
        if not svc.pending and i < len(pend) and pend[i][0] > clock.now():
            clock.advance(pend[i][0] - clock.now())
        while i < len(pend) and pend[i][0] <= clock.now():
            arrive_t[svc.submit(pend[i][1])] = pend[i][0]
            i += 1
        t0 = time.perf_counter()
        res = svc.flush() if svc.serve.mode == "flush" else svc.poll()
        clock.advance(time.perf_counter() - t0)
        for jid in res:
            lats.append(clock.now() - arrive_t.pop(jid))
    return lats


def _open_loop(cfg, fast: bool):
    """Continuous engine vs flush-when-idle under open-loop Poisson load.

    The workload is the one where a wave barrier genuinely costs tail
    latency: light requests (scale-6 ER graphs, ~10 ms) with a rare
    straggler (scale-10 rmat_bad, ~50x longer).  The flush server couples
    every request that arrives during a straggler's wave to that wave's
    barrier — they all wait it out, and the bunched-up queue makes the
    next wave bigger still.  The continuous engine keeps the straggler on
    its own lane and drains light requests at every chunk boundary, so
    only throughput (not the barrier) is shared.  Engines run lanes=1 /
    chunk_iters=2 here: the CPU sim executes vmapped lanes serially, so
    extra lanes only add idle-lane compute (the lanes>1 layouts are
    pinned bitwise by the scheduler tests; their parallel payoff needs
    real hardware).  Swept at 0.5x/1x/2x of the measured mean solo
    service time; every leg replays the same seeded arrival script.

    Compile hygiene (virtual-time latencies would otherwise swallow
    in-run XLA compiles): flush wave programs exist per pow2 batch size
    and wave composition is timing-dependent, so each distinct signature
    is precompiled across pow2 sizes up front; both modes then replay
    each script once untimed (identical arrival order -> identical engine
    dims and admission sequence) before the timed leg."""
    pool = [rmat.rmat_er(6, 8, seed=s) for s in range(7)]
    straggler = rmat.rmat_bad(10, 8, seed=0)
    pool.append(straggler)
    n_req = 32 if fast else 64

    def mk(mode):
        return ColoringService(
            P=P, cfg=cfg, clock=FakeClock(),
            serve=ServeConfig(mode=mode, lanes=1, chunk_iters=2,
                              solo_warm=False))

    # pow2 wave-size precompile per signature (lights all share one
    # bucket; straggler waves never bunch past a few)
    warm = mk("flush")
    for g, kmax in ((pool[0], n_req.bit_length()), (straggler, 3)):
        for k in range(kmax):
            for _ in range(2 ** k):
                warm.submit(g)
            warm.flush()
    # mean solo service time over the pool mix (min-of-N each)
    solo = ColoringService(P=P, cfg=cfg, clock=FakeClock(),
                           serve=ServeConfig(mode="flush"))
    solo.prewarm(pool)
    t_each = []
    for g in pool:
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            solo.submit(g); solo.flush()
            best = min(best, time.perf_counter() - t0)
        t_each.append(best)
    t_job = float(np.mean(t_each))

    sweeps, n_dominated = [], 0
    for load in (0.5, 1.0, 2.0):
        gap = t_job / load
        rng = np.random.default_rng(7)
        ts = np.cumsum(rng.exponential(gap, size=n_req))
        idx = rng.integers(0, len(pool), size=n_req)
        script = [(float(t), pool[int(j)]) for t, j in zip(ts, idx)]
        rec = dict(load=load, mean_gap_ms=gap * 1e3, n_requests=n_req,
                   n_stragglers=int((idx == len(pool) - 1).sum()))
        for mode in ("flush", "continuous"):
            _drive_open_loop(mk(mode), script)      # exact-script warm
            s = mk(mode)
            lats = _drive_open_loop(s, script)
            p50, p99 = _pcts(lats)
            st = s.stats()
            rec[mode] = dict(
                p50_ms=p50, p99_ms=p99,
                shed_rate=st["n_shed"] / n_req,
                routes={k: st[k] for k in ("solo", "batch", "lane")
                        if st[k]})
        rec["continuous_dominates_p99"] = (
            rec["continuous"]["p99_ms"] < rec["flush"]["p99_ms"])
        n_dominated += rec["continuous_dominates_p99"]
        sweeps.append(rec)
    return dict(t_job_ms=t_job * 1e3, t_each_ms=[t * 1e3 for t in t_each],
                sweeps=sweeps,
                n_rates_continuous_dominates_p99=n_dominated)


def run(fast: bool = True, out_path: str | Path = "BENCH_serve.json"):
    K = 8
    # scheme left at the default ("auto" unless $REPRO_SCHEME): each bucket
    # picks sparse vs allgather from modeled wire bytes at trace time; the
    # pow2-rung plans keep either choice compile-stable.  First Fit:
    # identical colorings on padded and unpadded layouts, so the two paths
    # are comparable bitwise.
    cfg = PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=512),
        recolor=RecolorConfig(max_colors=MC),
        n_iters=K, base_perm="nd", seed=0)
    # the throughput legs pin the batch-synchronous (flush) router: they
    # measure cost-model routing vs sequential dispatch, not scheduling
    svc = ColoringService(P=P, cfg=cfg, serve=ServeConfig(mode="flush"))

    def seq(graphs, ids):
        """The pre-batching server shape: per-graph partition + dispatch,
        same request-id-folded keys as the service (identical colorings)."""
        ck0, rk0 = jax.random.key(cfg.color.seed), jax.random.key(cfg.seed)
        out = []
        for g, i in zip(graphs, ids):
            pg = partition_graph(g, P)
            view, _ = pipeline_sim(
                pg, compute_order(pg, ordering.INTERNAL_FIRST), cfg,
                color_key=jax.random.fold_in(ck0, i),
                recolor_key=jax.random.fold_in(rk0, i))
            out.append(pg.gather_global_colors(np.asarray(view)))
        return out

    def serve(graphs):
        """Submit + flush through the cost-model router; returns
        (colors list in submit order, per-request latencies, route mix)."""
        ids = [svc.submit(g) for g in graphs]
        res = svc.flush()
        return (ids, [res[i]["colors"] for i in ids],
                [res[i]["latency_s"] for i in ids],
                sum(res[i]["route"] == "solo" for i in ids))

    wave0, wave1 = _wave(fast, seed=0), _wave(fast, seed=1)

    # ---- wave 0: cold, both sides; then prewarm the one-lane programs
    t0 = time.time(); seq(wave0, range(10_000, 10_000 + N_GRAPHS))
    t_seq_w0 = time.time() - t0
    t0 = time.time(); serve(wave0); t_svc_w0 = time.time() - t0
    t_prewarm = svc.prewarm(wave0)

    # ---- fresh traffic: the service routes by cache probe — wave-0
    # signatures go solo, new signatures share batch-lane compiles; the
    # sequential loop recompiles (data-dependent shapes).  The service is
    # timed FIRST: the program cache is process-wide, so the other order
    # would hand it the baseline's freshly compiled exact-dims programs.
    t0 = time.time()
    ids1, c_svc, fresh_lats, fresh_solo = serve(wave1)
    svc_s = time.time() - t0
    t0 = time.time()
    c_seq = seq(wave1, range(20_000, 20_000 + N_GRAPHS))
    seq_s = time.time() - t0

    # identical results (request-id-folded keys are route-independent) —
    # seq() must fold the same ids the service assigned
    c_seq = seq(wave1, ids1)
    for g, a, b in zip(wave1, c_seq, c_svc):
        assert np.array_equal(a, b), "paths disagree"
        assert_valid(g, b, what="served coloring")

    # ---- warm same-program leg: prewarm wave 1's new signatures, then
    # resubmit verbatim — all-solo via the cost model — vs the warm
    # sequential loop (interleaved min-of-REPEAT)
    svc.prewarm(wave1)
    t_seq_w, t_svc_w, warm_lats, warm_solo = [], [], [], 0
    for _ in range(REPEAT):
        ids_r = list(range(svc._next_id, svc._next_id + N_GRAPHS))
        t0 = time.time(); seq(wave1, ids_r); t_seq_w.append(time.time() - t0)
        t0 = time.time(); _, _, lats, solo = serve(wave1)
        t_svc_w.append(time.time() - t0)
        warm_lats, warm_solo = lats, solo
    seq_warm_s, svc_warm_s = min(t_seq_w), min(t_svc_w)

    st = svc.stats()
    cache = program_cache_stats()
    hit_rate = cache["hits"] / max(cache["hits"] + cache["misses"], 1)
    fresh_p50, fresh_p99 = _pcts(fresh_lats)
    warm_p50, warm_p99 = _pcts(warm_lats)
    pgs1 = [partition_graph(g, P) for g in wave1]
    rec = dict(
        n_graphs=N_GRAPHS, P=P, K=K, max_colors=MC, repeat=REPEAT,
        n_buckets=len(bucket_graphs(pgs1)),
        n_vertices=[g.n for g in wave1],
        warmup_seq_s=t_seq_w0, warmup_batched_s=t_svc_w0,
        prewarm_s=t_prewarm,
        seq_s=seq_s, batched_s=svc_s,
        speedup=seq_s / max(svc_s, 1e-9),
        graphs_per_s_seq=N_GRAPHS / seq_s,
        graphs_per_s_batched=N_GRAPHS / svc_s,
        fresh_solo=fresh_solo, fresh_p50_ms=fresh_p50, fresh_p99_ms=fresh_p99,
        seq_warm_s=seq_warm_s, batched_warm_s=svc_warm_s,
        warm_speedup=seq_warm_s / max(svc_warm_s, 1e-9),
        warm_solo=warm_solo, warm_p50_ms=warm_p50, warm_p99_ms=warm_p99,
        program_cache=dict(hits=cache["hits"], misses=cache["misses"],
                           traces=cache["traces"], hit_rate=hit_rate),
        routes=dict(solo=st["solo"], batch=st["batch"]),
        identical=True,
        note="fresh-wave dispatch after warmup+prewarm; sequential "
             "per-graph dispatch recompiles on every fresh graph "
             "(data-dependent shapes), the service routes by program-cache "
             "probe (hit -> solo dispatch, miss -> shared batch compile); "
             "*_warm_s resubmits wave 1 verbatim, all-solo, everything "
             "cached both sides; open_loop sweeps Poisson arrivals through "
             "the continuous lane engine vs the flush-when-idle server on "
             "the hybrid virtual/wall clock")
    rec["open_loop"] = _open_loop(cfg, fast)
    emit(f"serve/rmat_mix{N_GRAPHS}/P{P}/batched", svc_s * 1e6,
         f"seq_us={seq_s * 1e6:.0f};x={rec['speedup']:.2f};"
         f"gps={rec['graphs_per_s_batched']:.1f};"
         f"warm_x={rec['warm_speedup']:.2f};hit={hit_rate:.2f};"
         f"p50={warm_p50:.1f}ms;p99={warm_p99:.1f}ms;"
         f"buckets={rec['n_buckets']};"
         f"ol_p99_wins={rec['open_loop']['n_rates_continuous_dominates_p99']}"
         f"/3")
    Path(out_path).write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    run()
