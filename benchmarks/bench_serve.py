"""Batched multi-graph dispatch vs a sequential per-graph loop (ISSUE 5).

The serving workload: a *stream* of small-to-medium conflict graphs, each
needing the fused color->recolor pipeline.  Real traffic keeps producing
fresh graphs, and a fresh graph is a fresh XLA program under per-graph
dispatch — its padded shapes (``maxd``, ``m_local_max``, ghost/boundary
widths) are data-dependent, so the jit cache never converges.  The batched
service collapses that: pow2 shape buckets (``bucket_graphs``), pow2 batch
lanes (``color_many(pad_batch=True)``) and the shape-only all-gather
exchange make the program set finite, so steady-state traffic runs fully
compiled.

Protocol (both paths see the same fresh wave; First-Fit selection makes
their colorings identical, asserted):

  - wave 0 warms both paths (every program either side will ever cache);
  - wave 1 is fresh traffic: **sequential** = the repo's pre-batching
    dispatch, one ``pipeline_sim`` per original graph — new shapes, new
    compiles, every wave; **batched** = one ``color_many`` call — every
    bucket program already cached;
  - ``*_warm_s`` re-dispatches wave 1 verbatim (everything cached both
    sides, interleaved min-of-N): the pure batched-vs-looped execution gap
    on this CPU sim, reported for honesty — on CPU the compile-amortization
    is the win; the vmap fusion itself targets TPU lanes.

Acceptance (ISSUE 5): >= 3x throughput (graphs/sec) on a 64-graph RMAT mix
at P=4.  Writes BENCH_serve.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                        assert_valid, bucket_graphs, color_many,
                        compute_order, ordering, partition_graph,
                        pipeline_sim, rmat)

from .common import emit

MC = 512
P = 4
N_GRAPHS = 64
REPEAT = 3          # warm legs only: min-of-REPEAT, interleaved


def _wave(fast: bool, seed: int):
    """A fresh 64-graph RMAT request wave (three classes, mixed scales)."""
    lo, hi = (6, 8) if fast else (8, 10)
    rng = np.random.default_rng(seed)
    gens = (rmat.rmat_er, rmat.rmat_good, rmat.rmat_bad)
    return [gens[i % 3](int(rng.integers(lo, hi + 1)), 8,
                        seed=int(rng.integers(1 << 30)))
            for i in range(N_GRAPHS)]


def run(fast: bool = True, out_path: str | Path = "BENCH_serve.json"):
    K = 8
    # allgather: program depends on shapes only (the sparse plan's static
    # round schedule is data-derived and would retrace per wave — see
    # launch/serve_coloring.default_config); First Fit: identical colorings
    # on padded and unpadded layouts, so both paths are comparable bitwise.
    cfg = PipelineConfig(
        color=ColorConfig(max_colors=MC, superstep=512, scheme="allgather"),
        recolor=RecolorConfig(max_colors=MC, scheme="allgather"),
        n_iters=K, base_perm="nd", seed=0)

    def seq(graphs):
        """The pre-batching server shape: per-graph partition + dispatch."""
        out = []
        for g in graphs:
            pg = partition_graph(g, P)
            view, _ = pipeline_sim(
                pg, compute_order(pg, ordering.INTERNAL_FIRST), cfg)
            out.append(pg.gather_global_colors(np.asarray(view)))
        return out

    def bat(graphs):
        """The service shape: bucket, pad, one batched program per bucket."""
        pgs = [partition_graph(g, P) for g in graphs]
        return [r["colors"]
                for r in color_many(pgs, cfg, pad_batch=True)]

    wave0, wave1 = _wave(fast, seed=0), _wave(fast, seed=1)
    t0 = time.time(); seq(wave0); t_seq_w0 = time.time() - t0
    t0 = time.time(); bat(wave0); t_bat_w0 = time.time() - t0

    # fresh traffic: sequential compiles again (data-dependent shapes),
    # the batched bucket programs are already cached
    t0 = time.time(); c_seq = seq(wave1); seq_s = time.time() - t0
    t0 = time.time(); c_bat = bat(wave1); bat_s = time.time() - t0

    for g, a, b in zip(wave1, c_seq, c_bat):
        assert np.array_equal(a, b), "paths disagree"
        assert_valid(g, b, what="batched serve")

    # steady-state repeat of wave 1 (everything cached both sides)
    t_seq_w, t_bat_w = [], []
    for _ in range(REPEAT):
        t0 = time.time(); seq(wave1); t_seq_w.append(time.time() - t0)
        t0 = time.time(); bat(wave1); t_bat_w.append(time.time() - t0)
    seq_warm_s, bat_warm_s = min(t_seq_w), min(t_bat_w)

    pgs1 = [partition_graph(g, P) for g in wave1]
    rec = dict(
        n_graphs=N_GRAPHS, P=P, K=K, max_colors=MC, repeat=REPEAT,
        n_buckets=len(bucket_graphs(pgs1)),
        n_vertices=[g.n for g in wave1],
        warmup_seq_s=t_seq_w0, warmup_batched_s=t_bat_w0,
        seq_s=seq_s, batched_s=bat_s,
        speedup=seq_s / max(bat_s, 1e-9),
        graphs_per_s_seq=N_GRAPHS / seq_s,
        graphs_per_s_batched=N_GRAPHS / bat_s,
        seq_warm_s=seq_warm_s, batched_warm_s=bat_warm_s,
        warm_speedup=seq_warm_s / max(bat_warm_s, 1e-9),
        identical=True,
        note="fresh-wave dispatch after warmup; sequential per-graph "
             "dispatch recompiles on every fresh graph (data-dependent "
             "shapes), the batched pow2-bucket programs stay cached; "
             "*_warm_s repeats wave 1 verbatim with everything cached")
    emit(f"serve/rmat_mix{N_GRAPHS}/P{P}/batched", bat_s * 1e6,
         f"seq_us={seq_s * 1e6:.0f};x={rec['speedup']:.2f};"
         f"gps={rec['graphs_per_s_batched']:.1f};"
         f"warm_x={rec['warm_speedup']:.2f};buckets={rec['n_buckets']}")
    Path(out_path).write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    run()
