"""Shared benchmark plumbing: scaled graph suite, timing, CSV."""
from __future__ import annotations

import time

import numpy as np

from repro.core import rmat

# The paper's evaluation suite at CPU-feasible scale. "real" = FE-style
# stand-ins for the UF/Parasol graphs (Table 1), "rmat" = Table 2.
def suite_real(fast: bool = True):
    if fast:
        return {
            "grid2d": rmat.grid2d(96, 96, 9),
            "geo2d": rmat.geometric(8192, 28, seed=3),
            "geo3d": rmat.geometric(6144, 36, seed=4, dims=3),
        }
    return {
        "grid2d": rmat.grid2d(256, 256, 9),
        "grid3d": rmat.grid3d(32, 32, 32),
        "geo2d": rmat.geometric(1 << 15, 28, seed=3),
        "geo3d": rmat.geometric(1 << 14, 36, seed=4, dims=3),
    }


def suite_rmat(fast: bool = True):
    scale = 12 if fast else 14
    return {
        "rmat_er": rmat.rmat_er(scale, 8, seed=1),
        "rmat_good": rmat.rmat_good(scale, 8, seed=1),
        "rmat_bad": rmat.rmat_bad(scale, 8, seed=1),
    }


def timed(fn, *args, repeat: int = 1, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.time()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.time() - t0) / repeat


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
