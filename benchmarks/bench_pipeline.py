"""Fused device-resident pipeline vs the host-looped reference (ISSUE 4).

Reproduces the paper's quality-vs-iterations curve with both executions of
the same experiment:

  - **host loop** — ``color_graph_sim`` + ``recolor_iterations(fused=False)``:
    one jitted dispatch *per iteration*, color view and stats syncing through
    ``stats_to_host`` every time (the pre-pipeline shape);
  - **fused** — ``pipeline_sim`` / ``color_then_recolor``: initial coloring +
    K recoloring iterations in one ``lax.while_loop``, history unpacked once.

Per (graph, P, K) the sweep records wall time for both (compile excluded),
the speedup, and the per-iteration *distinct* color counts — which must match
bitwise (the fused loop is the host loop minus the host round-trips).  Color
counts here use the corrected quality metric (distinct classes in use, see
``check_coloring``/``n_colors_distinct``), not the max color id.

A second axis seeds the pipeline with First Fit vs Random-X initial
colorings (the paper's speed/quality presets): the RAND-seeded run pays more
initial colors but recovers through recoloring — on the skewed RMAT class at
P=16 it ends strictly below the FF-seeded run after the same K.

Writes BENCH_pipeline.json.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (ColorConfig, PipelineConfig, RecolorConfig,
                        assert_valid, color_graph_sim, colors_from_views,
                        compute_order, ordering, partition_graph,
                        pipeline_sim, recolor_iterations, rmat, selection)

from .common import emit

MC = 1024
REPEAT = 5          # min-of-REPEAT, host/fused interleaved: sim cells on a
                    # shared CPU drift by tens of percent between runs
P_SWEEP = (2, 4, 16)


def _graphs(fast: bool):
    if fast:
        return {
            "grid2d": rmat.grid2d(32, 32, 9),
            "rmat_good": rmat.rmat_good(9, 8, seed=1),
            "rmat_bad": rmat.rmat_bad(9, 8, seed=1),
        }
    return {
        "grid2d": rmat.grid2d(64, 64, 9),
        "rmat_er": rmat.rmat_er(11, 8, seed=1),
        "rmat_good": rmat.rmat_good(11, 8, seed=1),
        "rmat_bad": rmat.rmat_bad(11, 8, seed=1),
    }


def _timeit_pair(fns):
    """Interleaved min-of-REPEAT timing of competing implementations."""
    outs, times = [], []
    for fn in fns:                            # warmup / compile
        out = fn()
        jax.block_until_ready(out[0])
        outs.append(out)
        times.append([])
    for _ in range(REPEAT):
        for fn, ts in zip(fns, times):
            t0 = time.time()
            jax.block_until_ready(fn()[0])
            ts.append(time.time() - t0)
    return outs, [min(ts) for ts in times]


def _ccfg(sel=selection.FIRST_FIT, x=10):
    return ColorConfig(max_colors=MC, superstep=512, selection=sel,
                       random_x=x, seed=0)


def _pcfg(ccfg, K):
    return PipelineConfig(color=ccfg, recolor=RecolorConfig(max_colors=MC),
                          n_iters=K, base_perm="nd", seed=0)


def run(fast: bool = True, out_path: str | Path = "BENCH_pipeline.json"):
    K = 8 if fast else 16
    graphs = _graphs(fast)
    rec: dict = dict(max_colors=MC, repeat=REPEAT, n_iters=K, base_perm="nd",
                     note="color counts are distinct classes in use "
                          "(n_colors_distinct), not the max color id",
                     sweep=[], seeding=[])

    for gname, g in graphs.items():
        for P in P_SWEEP:
            pg = partition_graph(g, P)
            order = compute_order(pg, ordering.INTERNAL_FIRST)
            ccfg = _ccfg()
            rcfg = RecolorConfig(max_colors=MC)

            def host():
                view, _ = color_graph_sim(pg, order, ccfg)
                return recolor_iterations(pg, np.asarray(view), K, rcfg,
                                          base_perm="nd", seed=0,
                                          fused=False)

            def fused():
                return pipeline_sim(pg, order, _pcfg(ccfg, K))

            ((v_h, hist_h), (v_f, res_f)), (t_host, t_fused) = \
                _timeit_pair((host, fused))
            cs_host = [h["n_colors_distinct"] for h in hist_h]
            cs_fused = [h["n_colors_distinct"] for h in res_f["history"]]
            identical = (np.asarray(v_f) == np.asarray(v_h)).all() \
                and cs_host == cs_fused
            assert_valid(g, colors_from_views(pg, np.asarray(v_f)),
                         what=f"pipeline {gname} P={P}")
            row = dict(graph=gname, n=g.n, m=g.m, P=P, K=K,
                       host_s=t_host, fused_s=t_fused,
                       speedup=t_host / max(t_fused, 1e-9),
                       colors_per_iter=cs_fused,
                       colors_initial=res_f["color"]["n_colors_distinct"],
                       identical=bool(identical))
            rec["sweep"].append(row)
            emit(f"pipeline/{gname}/P{P}/fused", t_fused * 1e6,
                 f"host_us={t_host * 1e6:.1f};x={row['speedup']:.2f};"
                 f"colors={cs_fused[0]}->{cs_fused[-1]};"
                 f"identical={row['identical']}")

    # RAND-seeded vs FF-seeded quality after the same K (paper's trend:
    # a cheap randomized initial coloring + recoloring wins at scale)
    for gname, g in graphs.items():
        for P in P_SWEEP:
            pg = partition_graph(g, P)
            order = compute_order(pg, ordering.INTERNAL_FIRST)
            finals = {}
            for sname, sel, x in (("ff", selection.FIRST_FIT, 10),
                                  ("rand10", selection.RANDOM_X, 10),
                                  ("rand50", selection.RANDOM_X, 50)):
                _, res = pipeline_sim(pg, order, _pcfg(_ccfg(sel, x), K))
                finals[sname] = dict(
                    initial=res["color"]["n_colors_distinct"],
                    final=res["history"][-1]["n_colors_distinct"])
            row = dict(graph=gname, P=P, K=K, **{
                f"{k}_{f}": v[f] for k, v in finals.items()
                for f in ("initial", "final")})
            row["rand_beats_ff"] = bool(
                min(finals["rand10"]["final"], finals["rand50"]["final"])
                < finals["ff"]["final"])
            rec["seeding"].append(row)
            emit(f"pipeline/{gname}/P{P}/seeding", 0.0,
                 f"ff={finals['ff']['final']};"
                 f"rand10={finals['rand10']['final']};"
                 f"rand50={finals['rand50']['final']};"
                 f"rand_beats_ff={row['rand_beats_ff']}")

    Path(out_path).write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    run()
