"""Fig. 2 & 3: sequential recoloring — orderings × permutations × iterations,
and color-class permutation randomness schedules (ND-RAND%x, ND-RAND%2^i)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ColorConfig, RecolorConfig, color_graph_sim,
                        colors_from_views, compute_order, ordering,
                        partition_graph, recolor_iterations)

from .common import emit, geomean, suite_real


def initial(g, kind):
    pg = partition_graph(g, 1)
    order = compute_order(pg, kind)
    view, stats = color_graph_sim(
        pg, order, ColorConfig(max_colors=1024, superstep=4096))
    return pg, np.asarray(view), stats["n_colors"]


def fig2(fast: bool = True, iters: int = 12):
    """Orderings (NAT/LF/SL) × permutations (RV/NI/ND) over iterations,
    normalized to NAT colors (as the paper aggregates)."""
    graphs = suite_real(fast)
    base = {}
    results = {}
    for gname, g in graphs.items():
        pg, view_nat, nat0 = initial(g, ordering.NATURAL)
        base[gname] = nat0
        for okind in (ordering.NATURAL, ordering.LARGEST_FIRST,
                      ordering.SMALLEST_LAST):
            pg, view, c0 = initial(g, okind)
            for perm in ("rv", "ni", "nd"):
                t0 = time.time()
                _, hist = recolor_iterations(
                    pg, view, iters, RecolorConfig(max_colors=1024),
                    base_perm=perm)
                dt = time.time() - t0
                key = (okind, perm)
                results.setdefault(key, {})[gname] = dict(
                    c0=c0 / nat0, cs=[h["n_colors"] / nat0 for h in hist],
                    dt=dt)
    for (okind, perm), per_g in results.items():
        c0 = geomean(v["c0"] for v in per_g.values())
        cend = geomean(v["cs"][-1] for v in per_g.values())
        dt = sum(v["dt"] for v in per_g.values())
        emit(f"fig2/{okind}+RC-{perm}", dt / max(iters, 1) * 1e6,
             f"norm_colors_it0={c0:.3f};it{iters}={cend:.3f}")
    return results


def fig3(fast: bool = True, iters: int = 24, seeds: int = 3):
    """Randomness schedules with NAT/LF/SL orderings (paper: NAT benefits,
    LF/SL prefer pure ND at high iteration counts)."""
    graphs = suite_real(fast)
    schedules = {
        "nd": dict(base_perm="nd"),
        "rand": dict(base_perm="rand"),
        "nd-rand%5": dict(base_perm="nd", rand_every=5),
        "nd-rand%10": dict(base_perm="nd", rand_every=10),
        "nd-rand%2^i": dict(base_perm="nd", rand_pow2=True),
    }
    out = {}
    for okind in (ordering.NATURAL, ordering.SMALLEST_LAST):
        for sname, kw in schedules.items():
            finals = []
            for gname, g in graphs.items():
                pg, view, c0 = initial(g, okind)
                _, nat0 = pg, c0
                for s in range(seeds):
                    _, hist = recolor_iterations(
                        pg, view, iters, RecolorConfig(max_colors=1024),
                        seed=s, **kw)
                    finals.append(hist[-1]["n_colors"] / c0)
            val = geomean(finals)
            out[(okind, sname)] = val
            emit(f"fig3/{okind}/{sname}", 0.0,
                 f"final_norm_colors={val:.4f}")
    return out


def run(fast: bool = True):
    fig2(fast)
    fig3(fast, iters=12 if fast else 24, seeds=2 if fast else 3)


if __name__ == "__main__":
    run()
