"""Distance-2 coloring over the two-hop halo: comm scaling + quality.

Sweeps simulated P = 2..16 on a grid and two RMAT classes and records, per
exchange scheme (sparse neighbour-to-neighbour vs all-gather):

  - modeled bytes per full exchange at halo depth 2 (the two-hop ghost
    tables are larger, so the broadcast's O(P·max_b2) table grows faster
    than the sparse schedule's realized cross-structure bytes),
  - *measured* wire bytes from the D2 drivers (`stats["wire_bytes"]`) for
    speculative D2 coloring and one ND D2 recoloring iteration,
  - wall time (sim backend) and a coloring hash — the schemes must agree
    bitwise at depth 2 exactly as they do at depth 1.

Writes BENCH_d2.json.  ``tile=16`` bounds intra-tile speculative conflicts
(a hub neighbourhood is a D2 clique; see DESIGN.md §5).
"""
from __future__ import annotations

import hashlib
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (ColorConfig, RecolorConfig, check_coloring,
                        color_graph_sim, colors_from_views, compute_order,
                        ordering, partition_graph, recolor_sim, rmat)
from repro.core.comm import allgather_bytes_per_exchange

from .common import emit

MC = 1024
REPEAT = 2
P_SWEEP = (2, 4, 8, 16)


def _graphs(fast: bool):
    if fast:
        return {
            "grid2d": rmat.grid2d(32, 32, 9),
            "rmat_good": rmat.rmat_good(9, 8, seed=1),
            "rmat_bad": rmat.rmat_bad(9, 8, seed=1),
        }
    return {
        "grid2d": rmat.grid2d(64, 64, 9),
        "grid3d": rmat.grid3d(16, 16, 16),
        "rmat_er": rmat.rmat_er(11, 8, seed=1),
        "rmat_good": rmat.rmat_good(11, 8, seed=1),
        "rmat_bad": rmat.rmat_bad(11, 8, seed=1),
    }


def _hash(colors: np.ndarray) -> str:
    return hashlib.sha256(colors.astype(np.int32).tobytes()).hexdigest()[:16]


def _timeit(fn):
    jax.block_until_ready(fn()[0])            # warmup / compile
    t0 = time.time()
    for _ in range(REPEAT):
        out = fn()
        jax.block_until_ready(out[0])
    return out, (time.time() - t0) / REPEAT


def run(fast: bool = True, out_path: str | Path = "BENCH_d2.json"):
    graphs = _graphs(fast)
    rec: dict = dict(max_colors=MC, repeat=REPEAT, distance=2, sweep=[])

    for gname, g in graphs.items():
        for P in P_SWEEP:
            pg = partition_graph(g, P, halo=2)
            plan = pg.comm_plan
            order = compute_order(pg, ordering.INTERNAL_FIRST)
            row: dict = dict(
                graph=gname, n=g.n, m=g.m, P=P,
                n_rounds=len(plan.shifts),
                max_boundary=int(pg.max_boundary),
                max_ghost=int(pg.max_ghost),
                maxd2=int(pg.maxd2),
                modeled_sparse_bytes_per_ex=plan.bytes_per_exchange(),
                modeled_allgather_bytes_per_ex=allgather_bytes_per_exchange(
                    P, int(pg.max_boundary)),
            )
            hashes = {}
            for scheme in ("allgather", "sparse"):
                cfg = ColorConfig(max_colors=MC, superstep=256, tile=16,
                                  max_rounds=256, distance=2, seed=0,
                                  scheme=scheme)
                (view, st), t = _timeit(
                    lambda: color_graph_sim(pg, order, cfg))
                colors = colors_from_views(pg, np.asarray(view))
                hashes[scheme] = _hash(colors)
                row[f"color_{scheme}_s"] = t
                row[f"color_{scheme}_wire_bytes"] = st["wire_bytes"]
                row["d2_colors"] = st["n_colors"]
                rcfg = RecolorConfig(max_colors=MC, distance=2, scheme=scheme)
                key = jax.random.key(7)
                (v2, st2), t2 = _timeit(
                    lambda: recolor_sim(pg, view, "nd", rcfg, key=key))
                row[f"recolor_{scheme}_s"] = t2
                row[f"recolor_{scheme}_wire_bytes"] = st2["wire_bytes"]
                row["d2_colors_rc"] = st2["n_colors"]
            chk = check_coloring(g, colors, distance=2)
            row["d2_valid"] = bool(chk["valid"])
            row["colorings_identical"] = hashes["sparse"] == hashes["allgather"]
            row["color_hash"] = hashes["sparse"]
            row["bytes_reduction_color"] = 1.0 - (
                row["color_sparse_wire_bytes"]
                / max(row["color_allgather_wire_bytes"], 1))
            row["bytes_reduction_recolor"] = 1.0 - (
                row["recolor_sparse_wire_bytes"]
                / max(row["recolor_allgather_wire_bytes"], 1))
            rec["sweep"].append(row)
            emit(f"d2/{gname}/P{P}/color_sparse",
                 row["color_sparse_s"] * 1e6,
                 f"bytes={row['color_sparse_wire_bytes']};"
                 f"red={row['bytes_reduction_color']:.2f};"
                 f"colors={row['d2_colors']};valid={row['d2_valid']};"
                 f"identical={row['colorings_identical']}")
            emit(f"d2/{gname}/P{P}/recolor_sparse",
                 row["recolor_sparse_s"] * 1e6,
                 f"bytes={row['recolor_sparse_wire_bytes']};"
                 f"red={row['bytes_reduction_recolor']:.2f};"
                 f"colors={row['d2_colors_rc']}")

    Path(out_path).write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    run()
