"""Fig. 8, 9, 10: Random-X Fit — initial quality/runtime trade-off, with
0/1/2 ND recoloring iterations; derives the paper's "speed"/"quality" sets."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ColorConfig, RecolorConfig, color_graph_sim,
                        compute_order, ordering, partition_graph,
                        recolor_iterations, selection)

from .common import emit, geomean, suite_real


def combo(g, P, sel, x, okind, rc_iters, mc=1024, superstep=512):
    pg = partition_graph(g, P)
    order = compute_order(pg, okind)
    cfg = ColorConfig(max_colors=mc, superstep=superstep, selection=sel,
                      random_x=x)
    t0 = time.time()
    view, stats = color_graph_sim(pg, order, cfg)
    if rc_iters:
        view, hist = recolor_iterations(pg, np.asarray(view), rc_iters,
                                        RecolorConfig(max_colors=mc),
                                        base_perm="nd")
        colors = hist[-1]["n_colors"]
    else:
        colors = stats["n_colors"]
    return colors, time.time() - t0, stats


def run(fast: bool = True, P: int = 8):
    graphs = suite_real(fast)
    combos = [
        ("FI", selection.FIRST_FIT, 0, ordering.INTERNAL_FIRST),
        ("FS", selection.FIRST_FIT, 0, ordering.SMALLEST_LAST),
        ("R5I", selection.RANDOM_X, 5, ordering.INTERNAL_FIRST),
        ("R10I", selection.RANDOM_X, 10, ordering.INTERNAL_FIRST),
        ("R50I", selection.RANDOM_X, 50, ordering.INTERNAL_FIRST),
        ("R10S", selection.RANDOM_X, 10, ordering.SMALLEST_LAST),
    ]
    # normalize against FI, 0 iterations
    base: dict = {}
    for gname, g in graphs.items():
        c, t, _ = combo(g, P, selection.FIRST_FIT, 0,
                        ordering.INTERNAL_FIRST, 0)
        base[gname] = (c, max(t, 1e-9))
    for rc in (0, 1, 2):
        for cname, sel, x, okind in combos:
            ncs, nts, rounds = [], [], []
            for gname, g in graphs.items():
                c, t, st = combo(g, P, sel, x, okind, rc)
                ncs.append(c / base[gname][0])
                nts.append(t / base[gname][1])
                rounds.append(st["n_rounds"])
            emit(f"fig8910/{cname}ND{rc}", 0.0,
                 f"norm_colors={geomean(ncs):.3f};norm_time={geomean(nts):.3f};"
                 f"rounds={max(rounds)}")
    # paper presets
    emit("presets/speed", 0.0, "combo=FIxxND0")
    emit("presets/quality", 0.0, "combo=R(5-10)IxxND1")


if __name__ == "__main__":
    run()
