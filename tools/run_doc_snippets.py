"""Execute the ```python blocks of a markdown file (docs-can't-rot CI).

Every fenced ```python block runs in its own namespace, in file order.  A
block whose fence is immediately preceded by an HTML comment containing
``no-ci`` (e.g. ``<!-- no-ci: needs a TPU mesh -->``) is skipped — use it
for illustrative snippets that need hardware the CI runner lacks.

Usage:  PYTHONPATH=src python tools/run_doc_snippets.py README.md [...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

FENCE = re.compile(r"^(?P<skip><!--[^\n]*no-ci[^\n]*-->\s*\n)?"
                   r"^```python[^\n]*\n(?P<body>.*?)^```\s*$",
                   re.MULTILINE | re.DOTALL)


def run_file(path: str) -> int:
    text = Path(path).read_text()
    n = 0
    for m in FENCE.finditer(text):
        line = text[: m.start("body")].count("\n") + 1
        if m.group("skip"):
            print(f"-- {path}:{line}: skipped (no-ci)")
            continue
        n += 1
        print(f"== {path}:{line}: running snippet {n}")
        exec(compile(m.group("body"), f"{path}:snippet{n}", "exec"),
             {"__name__": f"__snippet{n}__"})
    print(f"== {path}: {n} snippet(s) ran")
    return n


if __name__ == "__main__":
    paths = sys.argv[1:] or ["README.md"]
    total = sum(run_file(p) for p in paths)
    assert total > 0, f"no runnable ```python blocks found in {paths}"
