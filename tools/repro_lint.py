"""repro-lint CLI: the SPMD-safety gate (AST rules + jaxpr trace audit).

Usage (from the repo root)::

    python -m tools.repro_lint src              # AST rules, baseline applied
    python -m tools.repro_lint src --trace-audit    # + jaxpr audit at P=2
    python -m tools.repro_lint src --write-baseline # refresh the baseline
    python -m tools.repro_lint src --json lint.json # machine-readable dump

Exit code 0 = no non-baselined findings (and, with ``--trace-audit``, every
jaxpr contract holds).  The committed baseline lives at
``tools/repro_lint_baseline.json``; rule catalog and suppression policy are
documented in DESIGN.md §9.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import engine, findings as findings_mod  # noqa: E402

DEFAULT_BASELINE = REPO_ROOT / "tools" / "repro_lint_baseline.json"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro_lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("targets", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline JSON (known legacy findings)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, including baselined ones")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings as the new baseline")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules to run")
    ap.add_argument("--trace-audit", action="store_true",
                    help="also run the jaxpr collective audit at P=2")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write findings + counts to this JSON file")
    args = ap.parse_args(argv)

    rules = args.rules.split(",") if args.rules else None
    targets = args.targets or ["src"]
    baseline = None if (args.no_baseline or args.write_baseline) \
        else args.baseline
    res = engine.run_lint(targets, root=REPO_ROOT, baseline=baseline,
                          rules=rules)

    if args.write_baseline:
        findings_mod.write_baseline(res.findings, args.baseline)
        print(f"wrote {len(set(res.findings))} baseline records "
              f"to {args.baseline}")
        return 0

    for f in res.findings:
        print(f.render())
    for e in res.errors:
        print(f"ERROR {e}", file=sys.stderr)

    audit_failures: list[str] = []
    if args.trace_audit:
        from repro.analysis.trace_audit import run_trace_audit
        audit = run_trace_audit()
        for line in audit.summary_lines():
            print(line)
        audit_failures = audit.failures

    counts = res.counts()
    summary = (" ".join(f"{k}={v}" for k, v in sorted(counts.items()))
               or "clean")
    print(f"repro-lint: {res.n_files} files, {len(res.findings)} new "
          f"finding(s) [{summary}], {len(res.baselined)} baselined, "
          f"{res.suppressed} suppression(s)")

    if args.json_out:
        payload = dict(
            n_files=res.n_files,
            counts=counts,
            findings=[dict(path=f.path, line=f.line, rule=f.rule,
                           message=f.message) for f in res.findings],
            baselined=len(res.baselined),
            suppressed=res.suppressed,
            errors=res.errors,
            trace_audit_failures=audit_failures,
        )
        Path(args.json_out).write_text(json.dumps(payload, indent=2) + "\n")

    return 1 if (res.findings or res.errors or audit_failures) else 0


if __name__ == "__main__":
    raise SystemExit(main())
